"""repro.delta: log-structured edge deltas over .gstore graphs.

Covers the full dynamic-graph loop: crash-safe append → overlay replay →
solver parity on all four backends → compact bit-identity vs fresh
ingest → incremental shard maintenance → epoch-aware refresh / warm
re-solve → serve-cache invalidation.  The scale-14 acceptance tier is
behind the ``slow`` marker.
"""

import json
import os
import shutil

import numpy as np
import pytest

from repro.core import from_edges
from repro.core.graph import bump_graph_version, ell_view_cached
from repro.data.graphs import rmat_edges
from repro.delta import (
    IncrementalSession,
    append_deltas,
    compact,
    effective_adjacency,
    entry_survives,
    read_segment,
    reset_affected,
    segment_name,
)
from repro.graphstore import (
    ArraySource,
    RmatEdgeSource,
    StoreFormatError,
    build_store,
    load_partition,
    open_store,
    partition_ell_store,
    partition_store,
    partition_store_2d,
    verify_store,
)
from repro.graphstore.format import (
    FORMAT_VERSION,
    FORMAT_VERSION_DELTA,
    crc32_file,
    read_manifest,
)
from repro.solver import SolverConfig, SteinerSolver


class _ChunkSource:
    """Edge source over an explicit chunk list (re-iterable)."""

    def __init__(self, n, chunks, describe="chunks"):
        self.n = int(n)
        self._chunks = chunks
        self.describe = describe

    def __iter__(self):
        for s, d, w in self._chunks:
            yield (
                np.asarray(s, np.int64),
                np.asarray(d, np.int64),
                np.asarray(w, np.float32),
            )


# ----------------------------------------------------------------------------
# the pure-Python fold model shared with the hypothesis property test
# ----------------------------------------------------------------------------


def apply_ops_model(base, ops_segments):
    """Reference fold of delta ops over an undirected edge list.

    ``base``: list of (u, v, w) in arrival order; ``ops_segments``: one
    record list per ``append_deltas`` call, in epoch order.  Returns
    ``(keep, adds_by_segment)`` where ``keep`` carries each surviving
    base edge with its original position and ``adds_by_segment`` holds
    each segment's surviving additions in arrival order — mirroring the
    documented record semantics: delete kills every live matching edge
    (base and earlier adds, both orientations), reweight sets the weight
    of every live matching edge, re-adding after a delete creates a new
    live edge.
    """
    base = [[u, v, w, True] for (u, v, w) in base]
    adds = []  # [u, v, w, alive, segment]
    for si, ops in enumerate(ops_segments):
        for rec in ops:
            if rec[0] == "add":
                adds.append([rec[1], rec[2], rec[3], True, si])
                continue
            key = frozenset((rec[1], rec[2]))
            for lst in (base, adds):
                for e in lst:
                    if e[3] and frozenset((e[0], e[1])) == key:
                        if rec[0] == "delete":
                            e[3] = False
                        else:  # reweight
                            e[2] = rec[3]
    keep = [
        (i, u, v, w) for i, (u, v, w, ok) in enumerate(base) if ok
    ]
    adds_by_seg = [
        [(u, v, w) for u, v, w, ok, s in adds if ok and s == si]
        for si in range(len(ops_segments))
    ]
    return keep, adds_by_seg


def reference_store_for(
    tmp, n, base, ops_segments, name="ref.gstore", chunk_edges=1 << 16
):
    """Fresh ingest of the model's final edge set, in canonical order.

    The surviving base edges keep the base ingest's chunk boundaries
    (per-row neighbor order is arrival order, so boundaries matter for
    bit-identity), followed by one chunk per append segment's surviving
    additions — exactly the effective edge stream ``compact()``
    re-ingests (``GraphStore.iter_coo``)."""
    keep, adds_by_seg = apply_ops_model(base, ops_segments)
    chunks = []
    for lo in range(0, max(len(base), 1), chunk_edges):
        part = [
            (u, v, w) for (i, u, v, w) in keep if lo <= i < lo + chunk_edges
        ]
        if part:
            s, d, w = zip(*part)
            chunks.append((np.asarray(s), np.asarray(d), np.asarray(w)))
    for seg in adds_by_seg:
        if seg:
            s, d, w = zip(*seg)
            chunks.append((np.asarray(s), np.asarray(d), np.asarray(w)))
    path, _ = build_store(_ChunkSource(n, chunks), tmp / name)
    return open_store(path, verify=False)


def assert_csr_equal(a, b):
    assert np.array_equal(np.asarray(a.indptr), np.asarray(b.indptr))
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
    assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))


def check_append_compact_roundtrip(tmp, n, base, ops_segments):
    """Shared core of the deterministic and hypothesis-driven tests:
    overlay view == compacted store == fresh ingest, bit for bit."""
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp / "g.gstore",
    )
    store = open_store(path, verify=False)
    for ops in ops_segments:
        if ops:
            append_deltas(store, ops)
    ops_segments = [ops for ops in ops_segments if ops]
    ref = reference_store_for(tmp, n, base, ops_segments)
    # overlay view (no rewrite yet)
    ip, ix, wt = store.effective_csr()
    assert np.array_equal(ip, np.asarray(ref.indptr))
    assert np.array_equal(ix, np.asarray(ref.indices))
    assert np.array_equal(wt, np.asarray(ref.weights))
    # compacted base (log folded in)
    compact(store)
    assert store.overlay is None
    assert_csr_equal(store, ref)
    assert store.manifest.get("weight_range") == ref.manifest.get(
        "weight_range"
    )
    verify_store(store.path)
    return store


def _mixed_ops(rng, n, base, k):
    """k random add/delete/reweight records; deletes and reweights target
    real base pairs so they actually bite."""
    ops = []
    pairs = [(u, v) for (u, v, _) in base]
    for _ in range(k):
        kind = rng.integers(0, 3)
        if kind == 0 or not pairs:
            u = int(rng.integers(0, n))
            v = int(rng.integers(0, n))
            if u == v:
                v = (v + 1) % n
            ops.append(("add", u, v, float(rng.integers(1, 50))))
        else:
            u, v = pairs[int(rng.integers(0, len(pairs)))]
            if kind == 1:
                ops.append(("delete", int(u), int(v)))
            else:
                ops.append(("reweight", int(u), int(v),
                            float(rng.integers(1, 50))))
    return ops


def _rmat_base(scale, ef, seed):
    """Undirected RMAT edge list + n (the same stream build_store ingests)."""
    src, dst, w, n = rmat_edges(scale, ef, seed=seed)
    return list(zip(src.tolist(), dst.tolist(), w.tolist())), n


# ----------------------------------------------------------------------------
# log + overlay + compact: bit-identity vs fresh ingest
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("trial", range(3))
def test_append_compact_bit_identical_to_fresh_ingest(tmp_path, trial):
    rng = np.random.default_rng(100 + trial)
    base, n = _rmat_base(7, 4, seed=trial)
    ops = _mixed_ops(rng, n, base, 40)
    check_append_compact_roundtrip(tmp_path, n, base, [ops])


def test_multi_segment_interleaving(tmp_path):
    """Ops split across several append calls fold identically to one log."""
    rng = np.random.default_rng(7)
    base, n = _rmat_base(7, 4, seed=9)
    ops = _mixed_ops(rng, n, base, 30)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    segments = [ops[lo : lo + 7] for lo in range(0, len(ops), 7)]
    for seg in segments:
        append_deltas(store, seg)
    assert store.epoch == len(segments)
    assert store.manifest["format_version"] == FORMAT_VERSION_DELTA
    ref = reference_store_for(tmp_path, n, base, segments)
    ip, ix, wt = store.effective_csr()
    assert np.array_equal(ix, np.asarray(ref.indices))
    assert np.array_equal(wt, np.asarray(ref.weights))
    compact(store)
    # epoch is retained across compaction; the layout drops back to the
    # delta-free revision
    assert store.epoch == len(segments)
    assert store.manifest["format_version"] == FORMAT_VERSION
    assert_csr_equal(store, ref)


def test_orphan_segment_is_invisible(tmp_path):
    """A crash between segment write and manifest rename leaves an orphan
    file that replay and verify both ignore."""
    base, n = _rmat_base(7, 4, seed=2)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    append_deltas(store, [("add", 1, 2, 3.0)])
    m_before = store.effective_csr()[0][-1]
    # simulate the torn append: a segment file the manifest never adopted
    shutil.copy(path / segment_name(1), path / segment_name(2))
    store.reload()
    assert store.epoch == 1  # manifest is the source of truth
    assert store.effective_csr()[0][-1] == m_before
    verify_store(path)  # orphan is not listed, so not checked


def test_append_validates_records(tmp_path):
    base, n = _rmat_base(6, 4, seed=1)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    for bad in (
        [("add", 0, 0, 1.0)],  # self-loop
        [("add", 0, n, 1.0)],  # out of range
        [("add", 0, 1, -2.0)],  # non-positive weight
        [("delete", 0, 1, 5.0)],  # delete takes no weight
        [("frobnicate", 0, 1)],  # unknown op
    ):
        with pytest.raises(ValueError):
            append_deltas(store, bad)
    assert store.epoch == 0  # nothing was applied


def test_delta_segment_crc_detected(tmp_path):
    base, n = _rmat_base(6, 4, seed=4)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    append_deltas(path, [("add", 1, 2, 3.0), ("add", 2, 3, 4.0)])
    seg = path / segment_name(1)
    raw = bytearray(seg.read_bytes())
    raw[-1] ^= 0xFF
    seg.write_bytes(bytes(raw))
    with pytest.raises(Exception):  # ChecksumError
        verify_store(path)


# ----------------------------------------------------------------------------
# incremental shard maintenance
# ----------------------------------------------------------------------------


def _shard_files(path):
    shdir = path / "shards"
    if not shdir.is_dir():
        return []
    return sorted("shards/" + f for f in os.listdir(shdir))


def test_compact_refreshes_1d_shards_incrementally(tmp_path):
    rng = np.random.default_rng(3)
    base, n = _rmat_base(9, 6, seed=11)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    partition_store(store, n_replica=2, n_blocks=4)
    partition_ell_store(store, k=8)
    store = open_store(path, verify=False)
    # deltas localized to one vertex block → most shard files untouched
    nb = store.partition_meta["nb"]
    ops = []
    for _ in range(20):
        u = int(rng.integers(0, nb))
        v = int(rng.integers(0, nb))
        if u == v:
            v = (v + 1) % nb
        ops.append(("add", u, v, float(rng.integers(1, 50))))
    append_deltas(store, ops)
    mtimes = {f: os.stat(path / f).st_mtime_ns for f in _shard_files(path)}
    stats = compact(store)
    assert stats.scheme == "1d"
    assert 0 < stats.shard_files_rewritten < stats.shard_files_total
    kept = [
        f for f in _shard_files(path)
        if os.stat(path / f).st_mtime_ns == mtimes[f]
    ]
    assert len(kept) == stats.shard_files_total - stats.shard_files_rewritten
    # ground truth: every shard byte-identical to a from-scratch partition
    # of the compacted CSR
    ref_dir = tmp_path / "ref.gstore"
    shutil.copytree(path, ref_dir)
    ref = open_store(ref_dir, verify=False)
    partition_store(ref, n_replica=2, n_blocks=4)
    partition_ell_store(ref, k=8)
    for f in _shard_files(path):
        assert crc32_file(path / f) == crc32_file(ref_dir / f), f
    # and the loader serves them
    part = load_partition(store)
    rpart = load_partition(ref)
    assert np.array_equal(np.asarray(part.src), np.asarray(rpart.src))
    assert np.array_equal(np.asarray(part.w), np.asarray(rpart.w))


def test_compact_refreshes_2d_shards_incrementally(tmp_path):
    rng = np.random.default_rng(5)
    base, n = _rmat_base(9, 6, seed=13)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    partition_store_2d(store, R=2, C=2)
    store = open_store(path, verify=False)
    nf = store.partition_meta["nf"]
    ops = [
        ("add", int(rng.integers(0, nf)), int(rng.integers(nf, 2 * nf)),
         float(rng.integers(1, 50)))
        for _ in range(10)
    ]
    append_deltas(store, ops)
    mtimes = {f: os.stat(path / f).st_mtime_ns for f in _shard_files(path)}
    stats = compact(store)
    assert stats.scheme == "2d"
    assert 0 < stats.shard_files_rewritten < stats.shard_files_total
    assert any(
        os.stat(path / f).st_mtime_ns == mtimes[f] for f in _shard_files(path)
    )
    ref_dir = tmp_path / "ref.gstore"
    shutil.copytree(path, ref_dir)
    ref = open_store(ref_dir, verify=False)
    partition_store_2d(ref, R=2, C=2)
    for f in _shard_files(path):
        assert crc32_file(path / f) == crc32_file(ref_dir / f), f


def test_stale_shards_refused_until_compact(tmp_path):
    base, n = _rmat_base(8, 4, seed=6)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    partition_store(store, n_replica=1, n_blocks=2)
    store = open_store(path, verify=False)
    append_deltas(store, [("add", 0, 1, 2.0)])
    assert not store.partition_fresh
    with pytest.raises(StoreFormatError):
        load_partition(store)
    compact(store)
    assert store.partition_fresh
    load_partition(store)  # refreshed shards load again


# ----------------------------------------------------------------------------
# solver parity across all four backends
# ----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def parity_delta_setup(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("delta_parity")
    rng = np.random.default_rng(42)
    base, n = _rmat_base(10, 6, seed=21)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp / "g.gstore",
    )
    store = open_store(path, verify=False)
    partition_store(store, n_replica=1, n_blocks=2)
    store = open_store(path, verify=False)
    ops = _mixed_ops(rng, n, base, 200)
    append_deltas(store, ops[:120])
    append_deltas(store, ops[120:])
    ref = reference_store_for(tmp, n, base, [ops[:120], ops[120:]])
    seeds = rng.choice(n, size=8, replace=False).astype(np.int32)
    return tmp, path, ref, seeds


BACKENDS = [
    ("single", {}),
    ("batch", {"batch_size": 2}),
    ("mesh1d", {"mesh_shape": (1, 1)}),
    ("mesh2d", {"mesh_shape": (1, 1)}),
]


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_backend_parity_overlay_vs_compact_vs_fresh(
    parity_delta_setup, backend, kw
):
    """The three faces of the mutated graph answer identically: overlay
    (log replayed at open), compacted base, and a from-scratch ingest of
    the final edge set."""
    tmp, path, ref, seeds = parity_delta_setup
    cfg = SolverConfig(backend=backend, mode="bucket", **kw)
    q = np.stack([seeds, seeds[::-1]]) if backend == "batch" else seeds

    overlay_store = open_store(path, verify=False)
    assert overlay_store.overlay is not None
    a = SteinerSolver(cfg).prepare(overlay_store).solve(q)

    cdir = tmp / f"compact_{backend}.gstore"
    shutil.copytree(path, cdir)
    cstore = open_store(cdir, verify=False)
    compact(cstore)
    b = SteinerSolver(cfg).prepare(cstore).solve(q)

    c = SteinerSolver(cfg).prepare(ref).solve(q)

    ta = np.asarray(a.total_distance)
    assert np.array_equal(ta, np.asarray(b.total_distance))
    assert np.array_equal(ta, np.asarray(c.total_distance))
    assert np.array_equal(np.asarray(a.num_edges), np.asarray(b.num_edges))
    assert np.array_equal(np.asarray(a.num_edges), np.asarray(c.num_edges))


# ----------------------------------------------------------------------------
# epoch-aware refresh + warm re-solve
# ----------------------------------------------------------------------------


def test_refresh_reuses_executables_and_tracks_epoch(tmp_path):
    base, n = _rmat_base(9, 5, seed=31)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    handle = SteinerSolver(
        SolverConfig(backend="single", mode="bucket")
    ).prepare(store)
    assert handle.epoch == 0
    rng = np.random.default_rng(0)
    seeds = rng.choice(n, size=6, replace=False).astype(np.int32)
    handle.solve(seeds)

    rep = handle.refresh()  # same epoch → no-op
    assert rep["refreshed"] == ()

    append_deltas(store, _mixed_ops(rng, n, base, 30))
    rep = handle.refresh()
    assert rep["from_epoch"] == 0 and rep["epoch"] == 1
    assert "graph" in rep["refreshed"]
    out = handle.solve(seeds)
    fresh = SteinerSolver(
        SolverConfig(backend="single", mode="bucket")
    ).prepare(open_store(path, verify=False)).solve(seeds)
    assert out.total_distance == fresh.total_distance


def test_warm_resolve_bit_exact_vs_cold(tmp_path):
    base, n = _rmat_base(9, 5, seed=33)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    cfg = SolverConfig(backend="single", mode="dense")
    handle = SteinerSolver(cfg).prepare(store)
    rng = np.random.default_rng(1)
    seeds = rng.choice(n, size=6, replace=False).astype(np.int32)
    cold0 = handle.solve(seeds)

    ops = _mixed_ops(rng, n, base, 25)
    info = append_deltas(store, ops)
    seg = read_segment(path / info["file"], info["epoch"])
    changed = np.unique(np.concatenate([seg.u, seg.v]).astype(np.int64))
    handle.refresh()

    warm_init, cells, n_reset = reset_affected(
        cold0.raw.state, seeds, changed, len(seeds)
    )
    warm = handle.solve(seeds, warm_state=warm_init)
    cold = handle.solve(seeds)
    assert float(warm.total_distance) == float(cold.total_distance)
    for f in ("dist", "lab", "pred"):
        assert np.array_equal(
            np.asarray(getattr(warm.raw.state, f)),
            np.asarray(getattr(cold.raw.state, f)),
        ), f


def test_warm_resolve_frontier_bit_exact_vs_cold(tmp_path):
    """Frontier-mode warm start (violated-edge dirty seeding) converges
    to the exact same fixpoint as its own cold solve AND as dense."""
    base, n = _rmat_base(9, 5, seed=34)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    cfg = SolverConfig(backend="single", mode="frontier", frontier_size=64)
    handle = SteinerSolver(cfg).prepare(store)
    rng = np.random.default_rng(2)
    seeds = rng.choice(n, size=6, replace=False).astype(np.int32)
    cold0 = handle.solve(seeds)

    ops = _mixed_ops(rng, n, base, 25)
    info = append_deltas(store, ops)
    seg = read_segment(path / info["file"], info["epoch"])
    changed = np.unique(np.concatenate([seg.u, seg.v]).astype(np.int64))
    handle.refresh()

    warm_init, _, _ = reset_affected(
        cold0.raw.state, seeds, changed, len(seeds)
    )
    warm = handle.solve(seeds, warm_state=warm_init)
    cold = handle.solve(seeds)
    dense = (
        SteinerSolver(SolverConfig(backend="single", mode="dense"))
        .prepare(store)
        .solve(seeds)
    )
    assert float(warm.total_distance) == float(cold.total_distance)
    assert float(warm.total_distance) == float(dense.total_distance)
    for f in ("dist", "lab", "pred"):
        assert np.array_equal(
            np.asarray(getattr(warm.raw.state, f)),
            np.asarray(getattr(cold.raw.state, f)),
        ), f
    # a fully-converged warm init yields an all-clean dirty set: zero rounds
    noop = handle.solve(seeds, warm_state=cold.raw.state)
    assert int(noop.telemetry.iterations) == 0
    assert float(noop.total_distance) == float(cold.total_distance)


def test_warm_state_rejected_off_supported_modes(tmp_path):
    base, n = _rmat_base(7, 4, seed=35)
    s, d, w = zip(*base)
    g = from_edges(
        np.asarray(s), np.asarray(d), np.asarray(w, np.float32), n
    )
    seeds = np.asarray([0, 1, 2, 3], np.int32)
    st0 = (
        SteinerSolver(SolverConfig(backend="single", mode="dense"))
        .prepare(g)
        .solve(seeds)
        .raw.state
    )
    batch = SteinerSolver(
        SolverConfig(backend="batch", mode="bucket", batch_size=2)
    ).prepare(g)
    with pytest.raises(ValueError):
        batch.solve(np.stack([seeds, seeds]), warm_state=st0)
    pallas = SteinerSolver(
        SolverConfig(backend="single", mode="pallas")
    ).prepare(g)
    with pytest.raises(ValueError):
        pallas.solve(seeds, warm_state=st0)


def test_incremental_session_multi_epoch_bit_exact(tmp_path):
    """The work-proportional epoch loop (ELL patch + warm rounds + pair-
    table repair) stays bit-identical to a cold solve of the mutated
    store across chained epochs — state, dmat, tree totals, edge count."""
    base, n = _rmat_base(9, 8, seed=3)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    rng = np.random.default_rng(0)
    seeds = rng.choice(n, size=24, replace=False).astype(np.int32)
    sess = IncrementalSession(
        store, seeds, ell_width=8, ell_pad_rows=256, frontier_size=32
    )
    handle = SteinerSolver(
        SolverConfig(
            backend="single", mode="frontier", ell_width=8, frontier_size=32
        )
    ).prepare(store)
    cold = handle.solve(seeds)
    assert sess.total_distance == float(cold.total_distance)
    assert np.array_equal(sess.dmat, np.asarray(cold.raw.dmat))

    for _ in range(3):
        ops = _mixed_ops(rng, n, base, 25)
        res = sess.apply_deltas(ops)
        handle.refresh()
        cold = handle.solve(seeds)
        assert res.total_distance == float(cold.total_distance)
        assert res.num_edges == int(cold.num_edges)
        assert np.array_equal(sess.dmat, np.asarray(cold.raw.dmat))
        for f in ("dist", "lab", "pred"):
            assert np.array_equal(
                np.asarray(getattr(sess.state, f)),
                np.asarray(getattr(cold.raw.state, f)),
            ), f


def test_ell_patcher_claims_pad_rows_and_exhausts(tmp_path):
    """Degree growth beyond a vertex's ELL block claims spare padding
    rows (solve parity preserved); with no spare rows it refuses loudly
    instead of corrupting the view."""
    base, n = _rmat_base(8, 4, seed=7)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    rng = np.random.default_rng(1)
    seeds = rng.choice(n, size=12, replace=False).astype(np.int32)
    sess = IncrementalSession(
        store, seeds, ell_width=4, ell_pad_rows=64, frontier_size=32
    )
    free0 = sess.patcher.free_rows
    assert free0 > 0
    # 40 new edges on one vertex → needs several extra ELL rows
    hub = int(seeds[0])
    ops = [
        ("add", hub, int((hub + 2 + i) % n), float(1 + i % 9))
        for i in range(40)
    ]
    res = sess.apply_deltas(ops)
    assert sess.patcher.free_rows < free0
    cold = (
        SteinerSolver(
            SolverConfig(
                backend="single", mode="frontier",
                ell_width=4, frontier_size=32,
            )
        )
        .prepare(store)
        .solve(seeds)
    )
    assert res.total_distance == float(cold.total_distance)
    for f in ("dist", "lab", "pred"):
        assert np.array_equal(
            np.asarray(getattr(sess.state, f)),
            np.asarray(getattr(cold.raw.state, f)),
        ), f

    # no padding at all → the same growth must raise, not alias rows
    store2 = open_store(
        build_store(
            ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
            tmp_path / "g2.gstore",
        )[0],
        verify=False,
    )
    sess2 = IncrementalSession(
        store2, seeds, ell_width=4, ell_pad_rows=1, frontier_size=32
    )
    with pytest.raises(RuntimeError, match="padding exhausted"):
        sess2.apply_deltas(ops)


def test_effective_adjacency_matches_effective_csr(tmp_path):
    """The per-vertex overlay gather (the O(deg) primitive under the
    incremental path) agrees with the full effective CSR."""
    base, n = _rmat_base(7, 4, seed=5)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    rng = np.random.default_rng(2)
    append_deltas(store, _mixed_ops(rng, n, base, 30))
    store.reload()
    indptr, indices, weights = store.effective_csr()
    verts = np.unique(rng.integers(0, n, size=20)).astype(np.int64)
    src, dst, wgt = effective_adjacency(store, verts)
    for v in verts:
        sel = src == v
        got = sorted(zip(dst[sel].tolist(), wgt[sel].tolist()))
        ref = sorted(
            zip(
                indices[indptr[v]:indptr[v + 1]].tolist(),
                weights[indptr[v]:indptr[v + 1]].tolist(),
            )
        )
        assert got == ref, int(v)


def test_entry_survives_label_rule():
    lab = np.asarray([0, 0, 1, 3, 3], np.int32)  # S=3 → vertices 3,4 unreached
    assert entry_survives(lab, np.asarray([3, 4]), 3)
    assert not entry_survives(lab, np.asarray([2, 3]), 3)
    assert entry_survives(lab, np.asarray([], np.int64), 3)


# ----------------------------------------------------------------------------
# serve-cache invalidation (epoch-aware SteinerServer)
# ----------------------------------------------------------------------------


def test_serve_revalidates_unaffected_and_invalidates_affected(tmp_path):
    from repro.serve import ServeConfig, SteinerServer

    # component A: ring over 0..15; component B: isolated pair 16-17
    n = 18
    s = np.asarray(list(range(16)) + [16])
    d = np.asarray([(i + 1) % 16 for i in range(16)] + [17])
    w = np.full(s.shape, 2.0, np.float32)
    build_store(ArraySource(s, d, w, n), tmp_path / "g.gstore")
    srv = SteinerServer(
        graph_path=str(tmp_path / "g.gstore"),
        config=ServeConfig(max_batch=2, buckets=(4,), mode="bucket"),
    )
    r0 = srv.query([0, 5, 9])

    # deltas confined to the unreached component: entry provably survives
    rep = srv.apply_deltas([("reweight", 16, 17, 7.0)])
    assert rep["revalidated"] == 1 and rep["invalidated"] == 0
    r1 = srv.query([0, 5, 9])
    assert r1.from_cache and r1.total_distance == r0.total_distance

    # deltas inside the served cells: evict + warm re-solve, result moves
    rep2 = srv.apply_deltas([("reweight", 0, 1, 50.0)])
    assert rep2["invalidated"] == 1 and rep2["revalidated"] == 0
    r2 = srv.query([0, 5, 9])
    assert not r2.from_cache
    assert r2.total_distance != r0.total_distance
    st = srv.stats()
    assert st["epoch"] == 2
    assert st["cache_invalidations"] == 1
    assert st["cache_revalidations"] == 1
    assert st["warm_resolves"] == 1
    text = srv.prometheus_text()
    assert "delta_epoch" in text and "cache_invalidations_total" in text


def test_serve_never_stale_after_deltas(tmp_path):
    """Staleness regression: after apply_deltas, every answer matches a
    fresh server booted from the mutated store — served entries whose
    cells intersect the changed vertices are never replayed."""
    from repro.serve import ServeConfig, SteinerServer

    rng = np.random.default_rng(8)
    base, n = _rmat_base(9, 6, seed=51)
    s, d, w = zip(*base)
    build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    cfg = ServeConfig(max_batch=4, mode="bucket")
    srv = SteinerServer(graph_path=str(tmp_path / "g.gstore"), config=cfg)
    qsets = [
        sorted(rng.choice(n, size=6, replace=False).tolist())
        for _ in range(6)
    ]
    srv.query_many(qsets)
    srv.apply_deltas(_mixed_ops(rng, n, base, 50))
    got = srv.query_many(qsets)
    ref_srv = SteinerServer(
        graph_path=str(tmp_path / "g.gstore"), config=cfg
    )
    want = ref_srv.query_many(qsets)
    for a, b in zip(got, want):
        assert a.total_distance == b.total_distance
        assert a.num_edges == b.num_edges
    st = srv.stats()
    assert st["epoch"] == 1
    assert st["warm_resolves"] + st["cache_revalidations"] > 0


# ----------------------------------------------------------------------------
# ell_view_cached version token (regression: id()-keyed memo aliasing)
# ----------------------------------------------------------------------------


def test_ell_memo_version_token_invalidates_and_never_aliases():
    s = np.asarray([0, 1, 2, 3])
    d = np.asarray([1, 2, 3, 0])
    w = np.ones(4, np.float32)
    g = from_edges(s, d, w, 4)
    a = ell_view_cached(g, 4)
    assert ell_view_cached(g, 4) is a
    # an in-place mutation bumps the version: the memo must rebuild
    bump_graph_version(g)
    b = ell_view_cached(g, 4)
    assert b is not a
    # a NEW graph object never hits another graph's entry, even if the
    # allocator hands it a recycled id() — tokens are process-unique
    del g
    g2 = from_edges(s, d, w, 4)
    c = ell_view_cached(g2, 4)
    assert c is not a and c is not b


# ----------------------------------------------------------------------------
# CLI: append / compact / verify
# ----------------------------------------------------------------------------


def test_cli_append_compact_verify_roundtrip(tmp_path, capsys):
    from repro.graphstore.__main__ import main

    store = str(tmp_path / "g.gstore")
    assert main(["--quiet", "build", store, "--scale", "7",
                 "--edge-factor", "4", "--seed", "3"]) == 0
    recs = tmp_path / "recs.json"
    recs.write_text(json.dumps(
        [["add", 1, 2, 3.5], ["delete", 3, 4], ["reweight", 5, 6, 9.0]]
    ))
    capsys.readouterr()
    assert main(["--quiet", "--json", "append", store,
                 "--records", str(recs), "--add", "7", "8", "2.5"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["epoch"] == 1 and doc["count"] == 4
    assert main(["--quiet", "--json", "verify", store]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] and doc["delta_segments"] == 1
    assert main(["--quiet", "--json", "compact", store]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["records_folded"] == 4 and doc["epoch"] == 1
    # corrupt one byte → verify exits nonzero
    with open(tmp_path / "g.gstore" / "weights.bin", "r+b") as h:
        h.seek(64)
        byte = h.read(1)
        h.seek(64)
        h.write(bytes([byte[0] ^ 0xFF]))
    capsys.readouterr()
    assert main(["--quiet", "--json", "verify", store]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False


def test_cli_append_requires_records(tmp_path):
    from repro.graphstore.__main__ import main

    store = str(tmp_path / "g.gstore")
    assert main(["--quiet", "build", store, "--scale", "6",
                 "--edge-factor", "4"]) == 0
    assert main(["--quiet", "append", store]) == 2


# ----------------------------------------------------------------------------
# scale-14 acceptance tier
# ----------------------------------------------------------------------------


@pytest.mark.slow
def test_scale14_thousand_deltas_parity_and_incremental(tmp_path):
    """ISSUE acceptance: ≥1k mixed deltas at scale 14 — overlay solve ==
    post-compact solve == full re-ingest solve on all four backends, and
    compaction rewrites only the affected shard files."""
    rng = np.random.default_rng(77)
    base, n = _rmat_base(14, 8, seed=99)
    s, d, w = zip(*base)
    path, _ = build_store(
        ArraySource(np.asarray(s), np.asarray(d), np.asarray(w), n),
        tmp_path / "g.gstore",
    )
    store = open_store(path, verify=False)
    partition_store(store, n_replica=1, n_blocks=8)
    partition_ell_store(store, k=16)
    store = open_store(path, verify=False)
    # 1200 mixed deltas confined to two vertex blocks
    nb = store.partition_meta["nb"]
    local = [(u, v, w_) for (u, v, w_) in base if u < 2 * nb and v < 2 * nb]
    ops = _mixed_ops(rng, 2 * nb, local, 1200)
    append_deltas(store, ops[:600])
    append_deltas(store, ops[600:])
    # model reference over the FULL base list (ops only touch low ids)
    ref = reference_store_for(
        tmp_path, n, base, [ops[:600], ops[600:]]
    )
    seeds = rng.choice(n, size=16, replace=False).astype(np.int32)

    mtimes = {f: os.stat(path / f).st_mtime_ns for f in _shard_files(path)}
    overlay = open_store(path, verify=False)
    results = {}
    for backend, kw in BACKENDS:
        cfg = SolverConfig(backend=backend, mode="bucket", **kw)
        q = np.stack([seeds, seeds[::-1]]) if backend == "batch" else seeds
        results[backend] = (
            np.asarray(SteinerSolver(cfg).prepare(overlay).solve(q)
                       .total_distance),
            q,
            cfg,
        )
    stats = compact(store)
    assert 0 < stats.shard_files_rewritten < stats.shard_files_total
    kept = [
        f for f in _shard_files(path)
        if os.stat(path / f).st_mtime_ns == mtimes[f]
    ]
    assert kept  # unaffected shard files preserved byte-for-byte (hardlink)
    for backend, (ta, q, cfg) in results.items():
        b = SteinerSolver(cfg).prepare(
            open_store(path, verify=False)
        ).solve(q)
        c = SteinerSolver(cfg).prepare(ref).solve(q)
        assert np.array_equal(ta, np.asarray(b.total_distance)), backend
        assert np.array_equal(ta, np.asarray(c.total_distance)), backend
    verify_store(path)
