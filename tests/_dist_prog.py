"""Subprocess body for multi-device distributed-Steiner tests.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8. Exits nonzero
on any mismatch; prints one OK line per case.
"""

import numpy as np


def main() -> None:
    import jax

    assert len(jax.devices()) == 8, jax.devices()

    from repro import compat
    from repro.core import ref
    from repro.core.dist_steiner import partition_edges, run_dist_steiner
    from repro.data.graphs import er_edges, rmat_edges

    mesh2 = compat.make_mesh((2, 4), ("data", "model"))
    mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))

    for trial in range(4):
        if trial % 2 == 0:
            src, dst, w, n = er_edges(50, 0.1, max_weight=9, seed=trial)
        else:
            src, dst, w, n = rmat_edges(6, 6, max_weight=20, seed=trial)
        rng = np.random.default_rng(trial)
        sd = rng.choice(n, size=6, replace=False).astype(np.int32)
        edges = list(zip(src.tolist(), dst.tolist(), w.tolist()))
        t_ref, d_ref = ref.mehlhorn_ref(n, edges, sd.tolist())

        # single-pod mesh, bucket mode, fused gather
        part = partition_edges(src, dst, w, n, n_replica=2, n_blocks=4)
        r = run_dist_steiner(mesh2, part, sd, mode="bucket")
        assert abs(r.total_distance - d_ref) < 1e-4, (r.total_distance, d_ref)
        assert r.edge_set() == t_ref

        # multi-pod mesh, dense mode, local-steps + chunked pair collectives.
        # Borůvka may break G'1 MST ties differently from Prim, yielding a
        # different (sometimes cheaper, never worse-bounded) valid tree —
        # so assert validity + bound instead of edge equality.
        part3 = partition_edges(src, dst, w, n, n_replica=4, n_blocks=2)
        r3 = run_dist_steiner(
            mesh3,
            part3,
            sd,
            replica_axes=("pod", "data"),
            mode="dense",
            local_steps=3,
            pair_chunks=4,
            mst_algo="boruvka",
        )
        assert ref.tree_is_valid(n, edges, sd.tolist(), r3.edge_set())
        opt = ref.dreyfus_wagner(n, edges, sd.tolist())
        bound = 2.0 * (1.0 - 1.0 / len(sd)) * opt + 1e-4
        assert opt - 1e-4 <= r3.total_distance <= bound, (r3.total_distance, opt)
        print(f"OK trial={trial} D={d_ref} iters2={r.iterations} iters3={r3.iterations}")

    # 2D (src×dst) partition: bit-identical output (beyond-paper engine)
    from repro.core.dist_steiner_2d import partition_edges_2d, run_dist_steiner_2d

    src, dst, w, n = er_edges(60, 0.1, max_weight=15, seed=21)
    sd = np.random.default_rng(21).choice(n, size=6, replace=False).astype(np.int32)
    edges = list(zip(src.tolist(), dst.tolist(), w.tolist()))
    t_ref, d_ref = ref.mehlhorn_ref(n, edges, sd.tolist())
    p2 = partition_edges_2d(src, dst, w, n, R=2, C=4)
    r2 = run_dist_steiner_2d(mesh2, p2, sd, mode="bucket")
    assert abs(r2.total_distance - d_ref) < 1e-4, (r2.total_distance, d_ref)
    assert r2.edge_set() == t_ref
    print(f"OK 2D partition: D={r2.total_distance} rounds={r2.iterations}")

    # local-steps reduces global rounds (async amortization, paper §IV)
    src, dst, w, n = rmat_edges(8, 6, max_weight=50, seed=9)
    sd = np.random.default_rng(9).choice(n, size=8, replace=False).astype(np.int32)
    part = partition_edges(src, dst, w, n, n_replica=2, n_blocks=4)
    r1 = run_dist_steiner(mesh2, part, sd, mode="dense", local_steps=1)
    r4 = run_dist_steiner(mesh2, part, sd, mode="dense", local_steps=4)
    assert abs(r1.total_distance - r4.total_distance) < 1e-4
    assert r4.iterations <= r1.iterations, (r4.iterations, r1.iterations)
    print(f"OK local-steps: {r1.iterations} -> {r4.iterations} global rounds")

    # mesh-frontier (sharded-ELL prioritized schedule, paper §IV message
    # prioritization): bit-identical tree to Δ-bucket on a real 2×4 mesh,
    # with strictly fewer messages per solve
    from repro.core.graph import from_edges
    from repro.solver import SolverConfig, SteinerSolver, trace_count

    src, dst, w, n = rmat_edges(7, 6, max_weight=30, seed=13)
    sd = np.random.default_rng(13).choice(n, size=6, replace=False).astype(np.int32)
    edges = list(zip(src.tolist(), dst.tolist(), w.tolist()))
    t_ref, d_ref = ref.mehlhorn_ref(n, edges, sd.tolist())
    g = from_edges(src, dst, w, n, pad_to=8)
    outs = {}
    for mode in ("bucket", "frontier"):
        cfg = SolverConfig(
            backend="mesh1d", mode=mode, mesh_shape=(2, 4),
            ell_width=8, frontier_size=16,
        )
        outs[mode] = SteinerSolver(cfg).prepare(g).solve(sd)
        assert abs(outs[mode].total_distance - d_ref) < 1e-4, (
            mode, outs[mode].total_distance, d_ref,
        )
        assert outs[mode].raw.edge_set() == t_ref, mode
    mb, mf = outs["bucket"].raw.messages, outs["frontier"].raw.messages
    assert mf < mb, (mf, mb)
    print(f"OK mesh-frontier 2x4: D={d_ref} messages {mb:.0f} -> {mf:.0f}")

    # prepared frontier handle: same-|S| queries re-trace zero times, and
    # duplicate-seed padding (the serve planner contract) stays inert
    handle = SteinerSolver(cfg).prepare(g)
    base = handle.solve(sd)
    t0 = trace_count("mesh1d")
    roll = handle.solve(np.roll(sd, 2))
    assert trace_count("mesh1d") == t0, "same-|S| mesh solve re-traced"
    assert roll.total_distance == base.total_distance
    padded = np.concatenate([sd, np.full(3, sd[0], np.int32)])
    rp = handle.solve(padded)
    assert rp.total_distance == base.total_distance
    assert rp.num_edges == base.num_edges
    np.testing.assert_array_equal(
        np.asarray(rp.raw.dist), np.asarray(base.raw.dist)
    )
    assert rp.raw.edge_set() == base.raw.edge_set()
    print("OK mesh-frontier trace-once + inert dup-seed padding")

    # per-rank flight recorder on real 2×4 meshes (paper §VI measurement
    # granularity): the (rounds, 8, 4) buffer must sum bit-exactly to the
    # global channels, and enabling it must change nothing else — same
    # tree, same counters, no extra retraces
    from repro.obs import flight

    for backend, mode, mkcfg in (
        (
            "mesh1d", "frontier",
            lambda pr: SolverConfig(
                backend="mesh1d", mode="frontier", mesh_shape=(2, 4),
                ell_width=8, frontier_size=16, telemetry_per_rank=pr,
            ),
        ),
        (
            "mesh2d", "bucket",
            lambda pr: SolverConfig(
                backend="mesh2d", mode="bucket", mesh_shape=(2, 4),
                telemetry_per_rank=pr,
            ),
        ),
    ):
        base_out = SteinerSolver(mkcfg(False)).prepare(g).solve(sd)
        assert base_out.telemetry.per_rank is None
        c0 = trace_count(backend)
        h = SteinerSolver(mkcfg(True)).prepare(g)
        pr_out = h.solve(sd)
        pr_out2 = h.solve(np.roll(sd, 1))
        assert trace_count(backend) == c0 + 1, "per-rank solve re-traced"
        t = pr_out.telemetry
        assert t.per_rank is not None and t.per_rank.shape[1] == 8, (
            t.per_rank.shape
        )
        assert t.per_rank.shape[0] == t.per_round.shape[0]
        # bit-exact attribution: rank rows sum to the global channels
        flight.check_consistency(
            t.per_rank, t.per_round, label=f"{backend}/{mode}"
        )
        flight.check_consistency(
            pr_out2.telemetry.per_rank, pr_out2.telemetry.per_round,
            label=f"{backend}/{mode} q2",
        )
        # the knob is observability-only: identical tree and counters
        assert pr_out.raw.edge_set() == base_out.raw.edge_set()
        assert pr_out.total_distance == base_out.total_distance
        assert t.messages == base_out.telemetry.messages
        assert t.relaxations == base_out.telemetry.relaxations
        assert t.iterations == base_out.telemetry.iterations
        np.testing.assert_array_equal(t.per_round, base_out.telemetry.per_round)
        rep = flight.analyze(t.per_rank, label=f"{backend}/{mode}")
        assert rep.n_ranks == 8 and rep.rounds == t.per_round.shape[0]
        assert np.all(rep.imbalance >= 1.0 - 1e-12)
        print(
            f"OK per-rank 2x4 {backend}/{mode}: rounds={rep.rounds} "
            f"msg_skew={rep.message_skew:.2f} "
            f"straggler={rep.stragglers[0] if rep.stragglers else None}"
        )


if __name__ == "__main__":
    main()
