"""Observability: metrics/trace units, zero-cost-when-disabled solver
integration, uniform telemetry parity, serve spans, CLI flags.

The load-bearing guarantees:

  * enabling obs never changes trees, counters, or executable counts —
    per-round telemetry rides every fixpoint loop unconditionally, so
    the toggle is host-side only (asserted bit-for-bit below);
  * ``SolveOutput.telemetry`` is the one uniform counter surface across
    all backends (Python ints; mesh/pallas f32 raws normalized), and its
    per-round rows sum exactly to the aggregate counters.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.core import from_edges
from repro.obs import (
    MetricsRegistry,
    Tracer,
    flight,
    parse_prometheus,
    regress,
    validate_chrome_trace,
)
from repro.obs.__main__ import main as obs_main
from repro.solver import SolverConfig, SteinerSolver, trace_count

from helpers import random_instance

ROOT = Path(__file__).resolve().parent.parent

MSG = obs.ROUND_CHANNELS.index("messages")
RELAX = obs.ROUND_CHANNELS.index("relaxations")


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test starts and ends with obs disabled and empty."""
    obs.reset()
    yield
    obs.reset()


def _instance(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    return from_edges(src, dst, w, n, pad_to=8), n, seeds


# ----------------------------------------------------------------------------
# metrics.py units
# ----------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "total requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0
    h = reg.histogram("lat_seconds")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.sum == 10.0
    assert h.percentile(50) == 2.5
    assert h.values() == (1.0, 2.0, 3.0, 4.0)


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError, match="only go up"):
        MetricsRegistry().counter("c_total").inc(-1)


def test_registry_get_or_create_and_kind_binding():
    reg = MetricsRegistry()
    assert reg.counter("x_total") is reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad-name")
    # label variants are distinct series under one name
    a = reg.counter("by_mode_total", labels={"mode": "a"})
    b = reg.counter("by_mode_total", labels={"mode": "b"})
    assert a is not b and len(reg.series("by_mode_total")) == 2


def test_prometheus_text_roundtrip():
    reg = MetricsRegistry()
    reg.counter("solves_total", "completed solves").inc(41)
    reg.gauge("queue_depth").set(3)
    h = reg.histogram("lat_seconds", labels={"path": "fresh"})
    h.observe(0.5)
    h.observe(1.5)
    samples = parse_prometheus(reg.prometheus_text())
    assert samples["solves_total"] == 41
    assert samples["queue_depth"] == 3
    assert samples['lat_seconds_count{path="fresh"}'] == 2
    assert samples['lat_seconds_sum{path="fresh"}'] == 2.0
    assert 'lat_seconds{path="fresh",quantile="0.5"}' in samples


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="not a Prometheus sample"):
        parse_prometheus("this is { not a sample\n")
    with pytest.raises(ValueError, match="bad sample value"):
        parse_prometheus("x_total twelve\n")


# ----------------------------------------------------------------------------
# trace.py units
# ----------------------------------------------------------------------------


def test_tracer_span_export_and_validate(tmp_path):
    tr = Tracer()
    with tr.span("outer", mode="frontier"):
        t0 = tr.now()
        tr.add_instant("checkpoint")
    tr.add_span("retro", t0, tr.now(), round=0)
    tr.add_counter("convergence", tr.now(), {"frontier": 5.0})
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == 4
    names = [e["name"] for e in doc["traceEvents"]]
    assert "process_name" in names and "outer" in names and "retro" in names


def test_validate_rejects_bad_traces():
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace([{"ph": "Z", "ts": 0.0}])
    with pytest.raises(ValueError, match="not monotonic"):
        validate_chrome_trace(
            [{"ph": "i", "ts": 5.0}, {"ph": "i", "ts": 1.0}]
        )
    with pytest.raises(ValueError, match="unclosed B"):
        validate_chrome_trace([{"ph": "B", "ts": 0.0, "name": "x"}])
    with pytest.raises(ValueError, match="E without matching B"):
        validate_chrome_trace([{"ph": "E", "ts": 0.0}])


# ----------------------------------------------------------------------------
# obs module switch — everything is inert until enable()
# ----------------------------------------------------------------------------


def test_disabled_by_default_everything_noops(tmp_path):
    assert not obs.enabled() and not obs.tracing()
    assert obs.counter("x_total") is None
    assert obs.gauge("x") is None and obs.histogram("x_s") is None
    assert obs.span("a") is obs.span("b")  # shared no-op object
    with obs.span("never-recorded"):
        pass
    obs.add_span("retro", 0.0, 1.0)
    obs.emit_round_telemetry(np.ones((2, 4)), 0.0, 1.0, label="x")
    assert obs.prometheus_text() == ""
    assert obs.export_chrome_trace(str(tmp_path / "t.json")) is False


def test_enable_disable_keeps_data():
    obs.enable()
    obs.counter("kept_total").inc(5)
    obs.disable()
    assert obs.counter("kept_total") is None  # no new recording
    assert "kept_total 5" in obs.registry().prometheus_text()
    obs.enable()  # idempotent re-enable keeps the registry
    assert obs.counter("kept_total").value == 5


# ----------------------------------------------------------------------------
# solver integration — enabling obs is invisible to the computation
# ----------------------------------------------------------------------------

OBS_SPECS = [
    ("single", "dense"),
    ("single", "bucket"),
    ("single", "frontier"),
    ("single", "pallas"),
    ("batch", "bucket"),
    ("mesh1d", "bucket"),
    ("mesh1d", "frontier"),
    ("mesh2d", "bucket"),
]


@pytest.mark.parametrize("backend,mode", OBS_SPECS)
def test_enable_is_bit_identical_and_never_retraces(backend, mode):
    g, n, seeds = _instance(1)
    cfg = SolverConfig(backend=backend, mode=mode, mesh_shape=(1, 1))
    handle = SteinerSolver(cfg).prepare(g)
    if backend == "batch":
        seeds = np.stack([seeds, np.roll(seeds, 1)])
    off = handle.solve(seeds)
    base = trace_count()
    obs.enable()
    on = handle.solve(seeds)
    assert trace_count() == base, "obs toggle must not build new executables"
    assert np.array_equal(
        np.asarray(off.total_distance), np.asarray(on.total_distance)
    )
    assert np.array_equal(np.asarray(off.num_edges), np.asarray(on.num_edges))
    assert on.telemetry.iterations == off.telemetry.iterations
    assert on.telemetry.messages == off.telemetry.messages
    assert on.telemetry.relaxations == off.telemetry.relaxations


@pytest.mark.parametrize(
    "backend,mode",
    [
        ("single", "bucket"),
        ("single", "frontier"),
        ("single", "pallas"),
        ("mesh1d", "bucket"),
        ("mesh1d", "frontier"),
        ("mesh2d", "bucket"),
    ],
)
def test_telemetry_matches_raw_counters(backend, mode):
    """SolveOutput.telemetry replaces digging through backend-native raw."""
    g, n, seeds = _instance(0)
    cfg = SolverConfig(backend=backend, mode=mode, mesh_shape=(1, 1))
    out = SteinerSolver(cfg).prepare(g).solve(seeds)
    t = out.telemetry
    assert isinstance(t.iterations, int)
    assert isinstance(t.messages, int) and isinstance(t.relaxations, int)
    if backend == "single":
        raw_it = out.raw.stats.iterations
        raw_msg, raw_rx = out.raw.stats.messages, out.raw.stats.relaxations
    else:
        raw_it = out.raw.iterations
        raw_msg, raw_rx = out.raw.messages, out.raw.relaxations
    assert t.iterations == int(raw_it)
    assert t.messages == int(round(float(raw_msg)))
    assert t.relaxations == int(round(float(raw_rx)))
    # per-round rows (ROUND_CHANNELS order) sum exactly to the aggregates
    assert t.per_round is not None and t.per_round.shape == (t.iterations, 4)
    assert int(t.per_round[:, MSG].sum()) == t.messages
    assert int(t.per_round[:, RELAX].sum()) == t.relaxations


def test_batch_telemetry_aggregates_lanes():
    g, n, _ = _instance(0)
    rng = np.random.default_rng(7)
    lanes = np.stack(
        [rng.choice(n, size=5, replace=False) for _ in range(2)]
    ).astype(np.int32)
    out = (
        SteinerSolver(SolverConfig(backend="batch", mode="bucket"))
        .prepare(g)
        .solve(lanes)
    )
    singles = [
        SteinerSolver(SolverConfig(backend="single", mode="bucket"))
        .prepare(g)
        .solve(lane)
        for lane in lanes
    ]
    t = out.telemetry
    assert t.iterations == max(s.telemetry.iterations for s in singles)
    assert t.messages == sum(s.telemetry.messages for s in singles)
    assert t.relaxations == sum(s.telemetry.relaxations for s in singles)
    assert t.per_round.shape == (t.iterations, 4)
    assert int(t.per_round[:, MSG].sum()) == t.messages


def test_telemetry_rounds_spill_and_zero():
    g, n, seeds = _instance(2)
    full = (
        SteinerSolver(SolverConfig(backend="single", mode="bucket"))
        .prepare(g)
        .solve(seeds)
    )
    iters = full.telemetry.iterations
    assert iters > 3  # the grid instance needs many rounds
    # H smaller than the round count: buffer truncates, aggregates exact
    small = (
        SteinerSolver(
            SolverConfig(backend="single", mode="bucket", telemetry_rounds=3)
        )
        .prepare(g)
        .solve(seeds)
    )
    assert small.telemetry.iterations == iters
    assert small.telemetry.messages == full.telemetry.messages
    assert small.telemetry.per_round.shape == (3, 4)
    assert np.array_equal(small.telemetry.per_round, full.telemetry.per_round[:3])
    # H=0: no buffer at all, identical trees and counters
    off = (
        SteinerSolver(
            SolverConfig(backend="single", mode="bucket", telemetry_rounds=0)
        )
        .prepare(g)
        .solve(seeds)
    )
    assert off.telemetry.per_round is None
    assert off.total_distance == full.total_distance
    assert off.telemetry.messages == full.telemetry.messages


def test_solve_emits_spans_and_convergence_tracks(tmp_path):
    g, n, seeds = _instance(1)
    obs.enable()
    handle = SteinerSolver(
        SolverConfig(backend="single", mode="frontier")
    ).prepare(g)
    handle.solve(seeds)
    path = tmp_path / "trace.json"
    assert obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) > 0
    names = {e["name"] for e in doc["traceEvents"]}
    assert "prepare" in names and "solve" in names
    assert "prepare:ell_build" in names
    assert any(n.startswith("round[") for n in names)
    assert any(n.startswith("convergence[") for n in names)
    rounds = [
        e for e in doc["traceEvents"] if e["name"].startswith("round[")
    ]
    assert all(e["args"]["synthetic_timing"] for e in rounds)
    samples = parse_prometheus(obs.prometheus_text())
    assert any(k.startswith("solver_messages_total") for k in samples)
    assert any(k.startswith("solver_solve_seconds_count") for k in samples)


# ----------------------------------------------------------------------------
# serve integration — registry-backed stats + per-query spans
# ----------------------------------------------------------------------------


def test_serve_stats_match_prometheus_dump():
    from repro.serve import ServeConfig, SteinerServer

    g, n, _ = _instance(0)
    srv = SteinerServer(
        g, ServeConfig(buckets=(8,), max_batch=4, cache_capacity=16)
    )
    rng = np.random.default_rng(0)
    q1 = rng.choice(n, size=4, replace=False).tolist()
    q2 = rng.choice(n, size=4, replace=False).tolist()
    srv.submit(q1)
    srv.submit(q2)
    srv.flush()
    srv.submit(q1)  # repeat → cache path
    srv.flush()
    st = srv.stats()
    samples = parse_prometheus(srv.prometheus_text())
    assert st["completed"] == 3
    assert samples["serve_queries_completed_total"] == st["completed"]
    assert samples["serve_cache_hits_total"] == st["cache_hits"]
    assert samples['serve_batches_total{bucket="8"}'] == sum(
        st["batches_per_bucket"].values()
    )
    assert samples["serve_lanes_run_total"] == st["lanes_run"]


def test_serve_emits_query_spans():
    from repro.serve import ServeConfig, SteinerServer

    g, n, _ = _instance(0)
    obs.enable()
    srv = SteinerServer(
        g, ServeConfig(buckets=(8,), max_batch=4, cache_capacity=16)
    )
    rng = np.random.default_rng(1)
    srv.submit(rng.choice(n, size=4, replace=False).tolist())
    srv.flush()
    names = {e["name"] for e in obs.tracer().events()}
    assert {
        "serve:queue_wait",
        "serve:assemble",
        "serve:solve",
        "serve:stash",
    } <= names
    assert validate_chrome_trace(obs.tracer().chrome_trace()) > 0


# ----------------------------------------------------------------------------
# CLI surfaces — graphstore flags and the obs validator
# ----------------------------------------------------------------------------


def _run_graphstore(args):
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.run(
        [sys.executable, "-m", "repro.graphstore", *args],
        capture_output=True,
        text=True,
        env=env,
    )


def test_graphstore_cli_json_and_quiet(tmp_path):
    store = tmp_path / "g.gstore"
    r = _run_graphstore(
        ["--json", "build", str(store), "--source", "rmat",
         "--scale", "6", "--edge-factor", "4"]
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads(r.stdout)  # stdout is exactly one JSON document
    assert doc["cmd"] == "build" and doc["m_directed"] > 0
    assert "built" in r.stderr  # progress rides the logger on stderr

    r = _run_graphstore(
        ["--json", "--quiet", "partition", str(store), "--blocks", "2"]
    )
    assert r.returncode == 0 and r.stderr == ""
    doc = json.loads(r.stdout)
    assert doc["cmd"] == "partition" and doc["shards"] == 2
    assert doc["meta"]["scheme"] == "1d"

    r = _run_graphstore(["--json", "--quiet", "info", str(store)])
    assert r.returncode == 0 and r.stderr == ""
    doc = json.loads(r.stdout)
    assert doc["partition"]["scheme"] == "1d"
    assert doc["degree"]["max"] >= doc["degree"]["min"]


def test_graphstore_cli_trace_and_metrics(tmp_path):
    store = tmp_path / "g.gstore"
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.txt"
    r = _run_graphstore(
        ["--quiet", "--trace", str(trace), "--metrics", str(metrics),
         "build", str(store), "--source", "rmat",
         "--scale", "6", "--edge-factor", "4"]
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads(trace.read_text())
    assert validate_chrome_trace(doc) > 0
    names = {e["name"] for e in doc["traceEvents"]}
    assert "ingest:build_store" in names
    assert "ingest:pass1_degrees" in names and "ingest:chunk" in names
    samples = parse_prometheus(metrics.read_text())
    assert samples["graphstore_ingest_edges_total"] > 0


def test_obs_cli_validate(tmp_path):
    tr = Tracer()
    with tr.span("build"):
        pass
    trace = tmp_path / "t.json"
    tr.export_chrome(str(trace))
    reg = MetricsRegistry()
    reg.counter("x_total").inc(2)
    metrics = tmp_path / "m.txt"
    metrics.write_text(reg.prometheus_text())
    ok = obs_main(
        ["validate", str(trace), "--metrics", str(metrics),
         "--require-span", "build"]
    )
    assert ok == 0
    assert obs_main(["validate", str(trace), "--require-span", "nope"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"ph": "Z", "ts": 0.0}]))
    assert obs_main(["validate", str(bad)]) == 1
    metrics.write_text("not { prometheus\n")
    assert obs_main(["validate", str(trace), "--metrics", str(metrics)]) == 1


# ----------------------------------------------------------------------------
# exposition-format conformance — pathological label values round-trip
# ----------------------------------------------------------------------------


def test_prometheus_label_escaping_roundtrip():
    reg = MetricsRegistry()
    weird = 'a\\b"c\nd,}e'
    reg.counter(
        "w_total", "line one\nline two \\ backslash", {"path": weird}
    ).inc(3)
    reg.gauge("g", "plain", {"x": "comma,brace}"}).set(7)
    text = reg.prometheus_text()
    # HELP newline must be escaped or the dump is not line-parseable
    [help_w] = [ln for ln in text.split("\n") if ln.startswith("# HELP w_total")]
    assert "\\n" in help_w
    samples = parse_prometheus(text)
    [wkey] = [k for k in samples if k.startswith("w_total")]
    assert samples[wkey] == 3.0
    assert samples['g{x="comma,brace}"}'] == 7.0
    # canonical keys are stable under re-parsing
    assert parse_prometheus(text) == samples


# ----------------------------------------------------------------------------
# tracer hygiene — leaked-span flush + atomic export
# ----------------------------------------------------------------------------


def test_flush_open_spans_records_leaked():
    tr = Tracer()
    cm = tr.span("abandoned")
    cm.__enter__()
    assert tr.flush_open_spans() == ["abandoned"]
    evs = [e for e in tr.events() if e["name"] == "abandoned"]
    assert evs and evs[0]["args"]["leaked"] is True
    assert tr.flush_open_spans() == []  # idempotent


def test_tracer_atexit_flushes_leaked_spans():
    code = (
        "from repro.obs.trace import Tracer\n"
        "tr = Tracer()\n"
        "cm = tr.span('leaky_span')\n"
        "cm.__enter__()\n"
    )
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    p = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert p.returncode == 0, p.stderr
    assert "flushed 1 span(s)" in p.stderr and "leaky_span" in p.stderr


def test_export_chrome_atomic(tmp_path):
    tr = Tracer()
    with tr.span("s"):
        pass
    path = tmp_path / "t.json"
    tr.export_chrome(str(path))
    assert validate_chrome_trace(json.loads(path.read_text())) > 0
    # no temp litter: the write went through tmp + os.replace
    assert list(tmp_path.glob("*.tmp.*")) == []


# ----------------------------------------------------------------------------
# serve gauges — pad_waste and queue depth on the scrape endpoint
# ----------------------------------------------------------------------------


def test_serve_pad_waste_and_queue_depth_gauges():
    from repro.serve import ServeConfig, SteinerServer

    g, n, _ = _instance(0)
    srv = SteinerServer(
        g, ServeConfig(buckets=(8,), max_batch=4, cache_capacity=16)
    )
    rng = np.random.default_rng(2)
    srv.submit(rng.choice(n, size=4, replace=False).tolist())
    s = parse_prometheus(srv.prometheus_text())
    assert s["serve_queue_depth"] == 1.0
    srv.flush()
    st = srv.stats()
    s = parse_prometheus(srv.prometheus_text())
    assert s["serve_queue_depth"] == 0.0
    # 1 real lane in a 4-lane batch → 3/4 padding; gauge == stats() value
    assert st["pad_waste"] == 0.75
    assert s["serve_pad_waste"] == pytest.approx(st["pad_waste"])


# ----------------------------------------------------------------------------
# per-rank flight recorder — (1,1) mesh unit coverage (the 2×4 forced-host
# assertions live in tests/_dist_prog.py)
# ----------------------------------------------------------------------------


def test_per_rank_config_validation():
    with pytest.raises(ValueError, match="telemetry_per_rank"):
        SolverConfig(backend="single", telemetry_per_rank=True)
    with pytest.raises(ValueError, match="telemetry_per_rank"):
        SolverConfig(
            backend="mesh1d", telemetry_per_rank=True, telemetry_rounds=0
        )


@pytest.mark.parametrize(
    "backend,mode",
    [
        ("mesh1d", "dense"),
        ("mesh1d", "bucket"),
        ("mesh1d", "frontier"),
        ("mesh2d", "dense"),
        ("mesh2d", "bucket"),
    ],
)
def test_per_rank_flight_recorder_single_device(backend, mode):
    g, n, seeds = _instance(2)
    kw = dict(ell_width=8, frontier_size=32) if mode == "frontier" else {}
    base = (
        SteinerSolver(
            SolverConfig(backend=backend, mode=mode, mesh_shape=(1, 1), **kw)
        )
        .prepare(g)
        .solve(seeds)
    )
    assert base.telemetry.per_rank is None
    out = (
        SteinerSolver(
            SolverConfig(
                backend=backend, mode=mode, mesh_shape=(1, 1),
                telemetry_per_rank=True, **kw,
            )
        )
        .prepare(g)
        .solve(seeds)
    )
    pr = out.telemetry.per_rank
    assert pr is not None
    assert pr.shape == (base.telemetry.per_round.shape[0], 1, 4)
    flight.check_consistency(pr, out.telemetry.per_round)
    # the knob is observability-only
    np.testing.assert_array_equal(
        out.telemetry.per_round, base.telemetry.per_round
    )
    assert out.total_distance == base.total_distance
    assert out.telemetry.messages == base.telemetry.messages


def test_per_rank_emits_rank_counter_tracks(tmp_path):
    g, n, seeds = _instance(1)
    obs.enable()
    out = (
        SteinerSolver(
            SolverConfig(
                backend="mesh1d", mode="frontier", mesh_shape=(1, 1),
                ell_width=8, frontier_size=32, telemetry_per_rank=True,
            )
        )
        .prepare(g)
        .solve(seeds)
    )
    assert out.telemetry.per_rank is not None
    path = tmp_path / "trace.json"
    assert obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) > 0
    names = {e["name"] for e in doc["traceEvents"]}
    assert "rank[mesh1d/frontier/0]" in names
    tracks = [
        e for e in doc["traceEvents"]
        if e["name"] == "rank[mesh1d/frontier/0]"
    ]
    assert len(tracks) == out.telemetry.per_rank.shape[0]
    assert set(tracks[0]["args"]) == set(obs.ROUND_CHANNELS)


# ----------------------------------------------------------------------------
# flight.py analytics
# ----------------------------------------------------------------------------


def test_flight_imbalance_and_stragglers():
    per_rank = np.zeros((3, 4, 4), np.float32)
    per_rank[0, :, MSG] = [4, 0, 0, 0]  # one rank does everything
    per_rank[1, :, MSG] = [1, 1, 1, 1]  # perfectly balanced
    # round 2: no activity at all → imbalance 1.0 by definition
    imb = flight.load_imbalance(per_rank)
    assert imb[0, MSG] == 4.0
    assert imb[1, MSG] == 1.0
    assert imb[2, MSG] == 1.0
    strag = flight.straggler_ranks(per_rank)
    # rank 0 carried the max in both active rounds; ties count everyone
    assert strag[0] == (0, 2)
    assert dict(strag) == {0: 2, 1: 1, 2: 1, 3: 1}
    rep = flight.analyze(per_rank, label="unit")
    assert rep.n_ranks == 4 and rep.rounds == 3
    assert rep.global_totals[MSG] == 8.0
    assert rep.peak_imbalance[MSG] == 4.0
    # mean over ACTIVE rounds only: (4.0 + 1.0) / 2
    assert rep.mean_imbalance[MSG] == pytest.approx(2.5)
    assert rep.message_skew == pytest.approx(5.0 / 2.0)


def test_flight_consistency_check():
    per_rank = np.arange(2 * 3 * 4, dtype=np.float32).reshape(2, 3, 4)
    per_round = per_rank.sum(axis=1)
    flight.check_consistency(per_rank, per_round)  # exact → no raise
    bad = per_round.copy()
    bad[1, MSG] += 1.0
    with pytest.raises(ValueError, match="round 1"):
        flight.check_consistency(per_rank, bad, label="unit")
    with pytest.raises(ValueError, match="per_rank must be"):
        flight.analyze(np.zeros((2, 3)))


def test_flight_dump_load_render(tmp_path):
    per_rank = np.ones((2, 2, 4), np.float32)
    per_rank[1, 0, MSG] = 5.0
    path = tmp_path / "flight.json"
    flight.dump_flight(
        str(path), per_rank, label="t", per_round=per_rank.sum(axis=1),
        extra={"graph": "unit"},
    )
    doc = flight.load_flight(str(path))
    np.testing.assert_array_equal(doc["per_rank"], per_rank)
    assert doc["extra"] == {"graph": "unit"}
    rep = flight.analyze(doc["per_rank"], label=doc["label"])
    txt = flight.render_report(rep)
    assert "Flight report: t" in txt and "messages" in txt
    md = flight.render_report(rep, fmt="markdown")
    assert "| channel |" in md
    with pytest.raises(ValueError, match="fmt"):
        flight.render_report(rep, fmt="html")
    notflight = tmp_path / "x.json"
    notflight.write_text("{}")
    with pytest.raises(ValueError, match="not a flight file"):
        flight.load_flight(str(notflight))


def test_obs_cli_report(tmp_path, capsys):
    per_rank = np.ones((2, 2, 4), np.float32)
    path = tmp_path / "flight.json"
    flight.dump_flight(
        str(path), per_rank, label="t", per_round=per_rank.sum(axis=1)
    )
    assert obs_main(["report", str(path)]) == 0
    out = capsys.readouterr().out
    assert "Flight report: t" in out and "message_skew" in out
    assert obs_main(["report", str(path), "--markdown"]) == 0
    assert "| channel |" in capsys.readouterr().out
    # a flight whose rank rows do NOT sum to the globals must fail
    flight.dump_flight(
        str(path), per_rank, label="t", per_round=per_rank.sum(axis=1) + 1
    )
    assert obs_main(["report", str(path)]) == 1


# ----------------------------------------------------------------------------
# regress.py — the perf gate itself
# ----------------------------------------------------------------------------


def _lo(value, metric="m_lo", mad_samples=None):
    samples = (value,) if mad_samples is None else tuple(mad_samples)
    return regress.MetricResult(metric, "ms", False, samples)


def _hi(value):
    return regress.MetricResult("m_hi", "qps", True, (value,))


def test_regress_compare_thresholds():
    base = {
        "m_lo": {"value": 100.0, "mad": 2.0},
        "m_hi": {"value": 50.0, "mad": 1.0},
    }
    # unknown metrics use the default 1.8 ratio:
    # lower-better limit = max(100·1.8, 100 + 5·2) = 180
    assert regress.compare([_lo(179.0)], base)[0].status == "ok"
    assert regress.compare([_lo(181.0)], base)[0].status == "regress"
    # MAD widens a tight ratio (noise awareness): slack 5·4 = 20 lifts
    # the 1.1-ratio limit from 110 to 120
    noisy = {"m_lo": {"value": 100.0, "mad": 4.0}}
    assert regress.compare([_lo(115.0)], noisy, max_ratio=1.1)[0].status == "ok"
    assert (
        regress.compare([_lo(125.0)], noisy, max_ratio=1.1)[0].status
        == "regress"
    )
    # ...but the slack is capped at 0.4·baseline: a hugely noisy
    # baseline cannot hide a genuine big regression
    wild = {"m_lo": {"value": 100.0, "mad": 1000.0}}
    assert regress.compare([_lo(141.0)], wild, max_ratio=1.1)[0].status == (
        "regress"
    )
    # higher-better mirror: limit = min(50/1.8, 50 − 5·1) = 27.78
    assert regress.compare([_hi(28.0)], base)[0].status == "ok"
    assert regress.compare([_hi(27.0)], base)[0].status == "regress"
    # missing baseline is reported, never a crash
    v = regress.compare(
        [regress.MetricResult("unknown", "ms", False, (1.0,))], base
    )[0]
    assert v.status == "missing" and v.baseline is None
    # render covers every verdict shape
    text = regress.render_verdicts(
        regress.compare([_lo(1.0), _hi(1.0)], base)
    )
    assert "m_lo" in text and "m_hi" in text


def test_regress_median_and_mad():
    r = _lo(0.0, mad_samples=(10.0, 11.0, 14.0))
    assert r.value == 11.0
    assert r.mad == 1.0  # median(|{10,11,14} − 11|) = median{1,0,3}


def test_regress_injection_is_time_derived_only(monkeypatch):
    res = [
        regress.MetricResult("t", "ms", False, (10.0,), time_derived=True),
        regress.MetricResult("q", "qps", True, (100.0,), time_derived=True),
        regress.MetricResult(
            "w", "messages", False, (500.0,), time_derived=False
        ),
    ]
    out = {r.metric: r for r in regress.apply_injection(res, 2.0)}
    assert out["t"].value == 20.0  # latency doubles
    assert out["q"].value == 50.0  # throughput halves
    assert out["w"].value == 500.0  # deterministic work untouched
    assert regress.apply_injection(res, 1.0) == res
    monkeypatch.setenv(regress.INJECT_ENV, "2.5")
    assert regress.injection_factor() == 2.5
    monkeypatch.setenv(regress.INJECT_ENV, "-1")
    with pytest.raises(ValueError):
        regress.injection_factor()


def test_regress_history_and_baseline_files(tmp_path):
    res = [_lo(10.0), _hi(100.0)]
    hist = tmp_path / "h.jsonl"
    assert regress.append_history(hist, res, quick=True, k=1) == 2
    assert regress.append_history(hist, res, quick=True, k=1) == 2
    rows = regress.load_history(hist)
    assert len(rows) == 4  # append-only
    assert rows[0]["metric"] == "m_lo" and rows[0]["value"] == 10.0
    assert "platform" in rows[0]["env"]
    base = tmp_path / "b.json"
    regress.write_baseline(base, res)
    bl = regress.load_baseline(base)
    assert bl["m_lo"]["value"] == 10.0
    assert bl["m_hi"]["higher_is_better"] is True
    assert list(tmp_path.glob("*.tmp.*")) == []  # atomic baseline write
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    with pytest.raises(ValueError, match="not a baseline"):
        regress.load_baseline(bad)


def test_bench_cli_gate(tmp_path, monkeypatch):
    def fake(k, quick):
        return [
            regress.MetricResult(
                "steiner_warm_ms_bucket", "ms", False, (10.0,) * k
            )
        ]

    monkeypatch.setattr(regress, "GROUPS", {"fake": fake})
    hist, base = tmp_path / "h.jsonl", tmp_path / "b.json"
    args = [
        "bench", "--only", "fake", "--k", "3",
        "--history", str(hist), "--baseline", str(base),
    ]
    # no baseline yet: warn-and-pass, unless --strict
    assert obs_main(args) == 0
    assert obs_main(args + ["--strict"]) == 1
    assert obs_main(args + ["--update-baseline"]) == 0
    assert regress.load_baseline(base)["steiner_warm_ms_bucket"]["value"] == 10.0
    # clean pass against its own baseline
    assert obs_main(args) == 0
    # unknown group is an error, not a silent no-op
    assert obs_main(["bench", "--only", "nope", "--history", str(hist),
                     "--baseline", str(base)]) == 1
    # injected 2× slowdown must fire the gate (policy ratio 1.8, mad 0)
    monkeypatch.setenv(regress.INJECT_ENV, "2.0")
    assert obs_main(args) == 1
    rows = regress.load_history(hist)
    assert len(rows) == 5 and rows[-1]["injected"] == 2.0
