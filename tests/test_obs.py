"""Observability: metrics/trace units, zero-cost-when-disabled solver
integration, uniform telemetry parity, serve spans, CLI flags.

The load-bearing guarantees:

  * enabling obs never changes trees, counters, or executable counts —
    per-round telemetry rides every fixpoint loop unconditionally, so
    the toggle is host-side only (asserted bit-for-bit below);
  * ``SolveOutput.telemetry`` is the one uniform counter surface across
    all backends (Python ints; mesh/pallas f32 raws normalized), and its
    per-round rows sum exactly to the aggregate counters.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.core import from_edges
from repro.obs import (
    MetricsRegistry,
    Tracer,
    parse_prometheus,
    validate_chrome_trace,
)
from repro.obs.__main__ import main as obs_main
from repro.solver import SolverConfig, SteinerSolver, trace_count

from helpers import random_instance

ROOT = Path(__file__).resolve().parent.parent

MSG = obs.ROUND_CHANNELS.index("messages")
RELAX = obs.ROUND_CHANNELS.index("relaxations")


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test starts and ends with obs disabled and empty."""
    obs.reset()
    yield
    obs.reset()


def _instance(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    return from_edges(src, dst, w, n, pad_to=8), n, seeds


# ----------------------------------------------------------------------------
# metrics.py units
# ----------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "total requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0
    h = reg.histogram("lat_seconds")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.sum == 10.0
    assert h.percentile(50) == 2.5
    assert h.values() == (1.0, 2.0, 3.0, 4.0)


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError, match="only go up"):
        MetricsRegistry().counter("c_total").inc(-1)


def test_registry_get_or_create_and_kind_binding():
    reg = MetricsRegistry()
    assert reg.counter("x_total") is reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad-name")
    # label variants are distinct series under one name
    a = reg.counter("by_mode_total", labels={"mode": "a"})
    b = reg.counter("by_mode_total", labels={"mode": "b"})
    assert a is not b and len(reg.series("by_mode_total")) == 2


def test_prometheus_text_roundtrip():
    reg = MetricsRegistry()
    reg.counter("solves_total", "completed solves").inc(41)
    reg.gauge("queue_depth").set(3)
    h = reg.histogram("lat_seconds", labels={"path": "fresh"})
    h.observe(0.5)
    h.observe(1.5)
    samples = parse_prometheus(reg.prometheus_text())
    assert samples["solves_total"] == 41
    assert samples["queue_depth"] == 3
    assert samples['lat_seconds_count{path="fresh"}'] == 2
    assert samples['lat_seconds_sum{path="fresh"}'] == 2.0
    assert 'lat_seconds{path="fresh",quantile="0.5"}' in samples


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="not a Prometheus sample"):
        parse_prometheus("this is { not a sample\n")
    with pytest.raises(ValueError, match="bad sample value"):
        parse_prometheus("x_total twelve\n")


# ----------------------------------------------------------------------------
# trace.py units
# ----------------------------------------------------------------------------


def test_tracer_span_export_and_validate(tmp_path):
    tr = Tracer()
    with tr.span("outer", mode="frontier"):
        t0 = tr.now()
        tr.add_instant("checkpoint")
    tr.add_span("retro", t0, tr.now(), round=0)
    tr.add_counter("convergence", tr.now(), {"frontier": 5.0})
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == 4
    names = [e["name"] for e in doc["traceEvents"]]
    assert "process_name" in names and "outer" in names and "retro" in names


def test_validate_rejects_bad_traces():
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace([{"ph": "Z", "ts": 0.0}])
    with pytest.raises(ValueError, match="not monotonic"):
        validate_chrome_trace(
            [{"ph": "i", "ts": 5.0}, {"ph": "i", "ts": 1.0}]
        )
    with pytest.raises(ValueError, match="unclosed B"):
        validate_chrome_trace([{"ph": "B", "ts": 0.0, "name": "x"}])
    with pytest.raises(ValueError, match="E without matching B"):
        validate_chrome_trace([{"ph": "E", "ts": 0.0}])


# ----------------------------------------------------------------------------
# obs module switch — everything is inert until enable()
# ----------------------------------------------------------------------------


def test_disabled_by_default_everything_noops(tmp_path):
    assert not obs.enabled() and not obs.tracing()
    assert obs.counter("x_total") is None
    assert obs.gauge("x") is None and obs.histogram("x_s") is None
    assert obs.span("a") is obs.span("b")  # shared no-op object
    with obs.span("never-recorded"):
        pass
    obs.add_span("retro", 0.0, 1.0)
    obs.emit_round_telemetry(np.ones((2, 4)), 0.0, 1.0, label="x")
    assert obs.prometheus_text() == ""
    assert obs.export_chrome_trace(str(tmp_path / "t.json")) is False


def test_enable_disable_keeps_data():
    obs.enable()
    obs.counter("kept_total").inc(5)
    obs.disable()
    assert obs.counter("kept_total") is None  # no new recording
    assert "kept_total 5" in obs.registry().prometheus_text()
    obs.enable()  # idempotent re-enable keeps the registry
    assert obs.counter("kept_total").value == 5


# ----------------------------------------------------------------------------
# solver integration — enabling obs is invisible to the computation
# ----------------------------------------------------------------------------

OBS_SPECS = [
    ("single", "dense"),
    ("single", "bucket"),
    ("single", "frontier"),
    ("single", "pallas"),
    ("batch", "bucket"),
    ("mesh1d", "bucket"),
    ("mesh1d", "frontier"),
    ("mesh2d", "bucket"),
]


@pytest.mark.parametrize("backend,mode", OBS_SPECS)
def test_enable_is_bit_identical_and_never_retraces(backend, mode):
    g, n, seeds = _instance(1)
    cfg = SolverConfig(backend=backend, mode=mode, mesh_shape=(1, 1))
    handle = SteinerSolver(cfg).prepare(g)
    if backend == "batch":
        seeds = np.stack([seeds, np.roll(seeds, 1)])
    off = handle.solve(seeds)
    base = trace_count()
    obs.enable()
    on = handle.solve(seeds)
    assert trace_count() == base, "obs toggle must not build new executables"
    assert np.array_equal(
        np.asarray(off.total_distance), np.asarray(on.total_distance)
    )
    assert np.array_equal(np.asarray(off.num_edges), np.asarray(on.num_edges))
    assert on.telemetry.iterations == off.telemetry.iterations
    assert on.telemetry.messages == off.telemetry.messages
    assert on.telemetry.relaxations == off.telemetry.relaxations


@pytest.mark.parametrize(
    "backend,mode",
    [
        ("single", "bucket"),
        ("single", "frontier"),
        ("single", "pallas"),
        ("mesh1d", "bucket"),
        ("mesh1d", "frontier"),
        ("mesh2d", "bucket"),
    ],
)
def test_telemetry_matches_raw_counters(backend, mode):
    """SolveOutput.telemetry replaces digging through backend-native raw."""
    g, n, seeds = _instance(0)
    cfg = SolverConfig(backend=backend, mode=mode, mesh_shape=(1, 1))
    out = SteinerSolver(cfg).prepare(g).solve(seeds)
    t = out.telemetry
    assert isinstance(t.iterations, int)
    assert isinstance(t.messages, int) and isinstance(t.relaxations, int)
    if backend == "single":
        raw_it = out.raw.stats.iterations
        raw_msg, raw_rx = out.raw.stats.messages, out.raw.stats.relaxations
    else:
        raw_it = out.raw.iterations
        raw_msg, raw_rx = out.raw.messages, out.raw.relaxations
    assert t.iterations == int(raw_it)
    assert t.messages == int(round(float(raw_msg)))
    assert t.relaxations == int(round(float(raw_rx)))
    # per-round rows (ROUND_CHANNELS order) sum exactly to the aggregates
    assert t.per_round is not None and t.per_round.shape == (t.iterations, 4)
    assert int(t.per_round[:, MSG].sum()) == t.messages
    assert int(t.per_round[:, RELAX].sum()) == t.relaxations


def test_batch_telemetry_aggregates_lanes():
    g, n, _ = _instance(0)
    rng = np.random.default_rng(7)
    lanes = np.stack(
        [rng.choice(n, size=5, replace=False) for _ in range(2)]
    ).astype(np.int32)
    out = (
        SteinerSolver(SolverConfig(backend="batch", mode="bucket"))
        .prepare(g)
        .solve(lanes)
    )
    singles = [
        SteinerSolver(SolverConfig(backend="single", mode="bucket"))
        .prepare(g)
        .solve(lane)
        for lane in lanes
    ]
    t = out.telemetry
    assert t.iterations == max(s.telemetry.iterations for s in singles)
    assert t.messages == sum(s.telemetry.messages for s in singles)
    assert t.relaxations == sum(s.telemetry.relaxations for s in singles)
    assert t.per_round.shape == (t.iterations, 4)
    assert int(t.per_round[:, MSG].sum()) == t.messages


def test_telemetry_rounds_spill_and_zero():
    g, n, seeds = _instance(2)
    full = (
        SteinerSolver(SolverConfig(backend="single", mode="bucket"))
        .prepare(g)
        .solve(seeds)
    )
    iters = full.telemetry.iterations
    assert iters > 3  # the grid instance needs many rounds
    # H smaller than the round count: buffer truncates, aggregates exact
    small = (
        SteinerSolver(
            SolverConfig(backend="single", mode="bucket", telemetry_rounds=3)
        )
        .prepare(g)
        .solve(seeds)
    )
    assert small.telemetry.iterations == iters
    assert small.telemetry.messages == full.telemetry.messages
    assert small.telemetry.per_round.shape == (3, 4)
    assert np.array_equal(small.telemetry.per_round, full.telemetry.per_round[:3])
    # H=0: no buffer at all, identical trees and counters
    off = (
        SteinerSolver(
            SolverConfig(backend="single", mode="bucket", telemetry_rounds=0)
        )
        .prepare(g)
        .solve(seeds)
    )
    assert off.telemetry.per_round is None
    assert off.total_distance == full.total_distance
    assert off.telemetry.messages == full.telemetry.messages


def test_solve_emits_spans_and_convergence_tracks(tmp_path):
    g, n, seeds = _instance(1)
    obs.enable()
    handle = SteinerSolver(
        SolverConfig(backend="single", mode="frontier")
    ).prepare(g)
    handle.solve(seeds)
    path = tmp_path / "trace.json"
    assert obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) > 0
    names = {e["name"] for e in doc["traceEvents"]}
    assert "prepare" in names and "solve" in names
    assert "prepare:ell_build" in names
    assert any(n.startswith("round[") for n in names)
    assert any(n.startswith("convergence[") for n in names)
    rounds = [
        e for e in doc["traceEvents"] if e["name"].startswith("round[")
    ]
    assert all(e["args"]["synthetic_timing"] for e in rounds)
    samples = parse_prometheus(obs.prometheus_text())
    assert any(k.startswith("solver_messages_total") for k in samples)
    assert any(k.startswith("solver_solve_seconds_count") for k in samples)


# ----------------------------------------------------------------------------
# serve integration — registry-backed stats + per-query spans
# ----------------------------------------------------------------------------


def test_serve_stats_match_prometheus_dump():
    from repro.serve import ServeConfig, SteinerServer

    g, n, _ = _instance(0)
    srv = SteinerServer(
        g, ServeConfig(buckets=(8,), max_batch=4, cache_capacity=16)
    )
    rng = np.random.default_rng(0)
    q1 = rng.choice(n, size=4, replace=False).tolist()
    q2 = rng.choice(n, size=4, replace=False).tolist()
    srv.submit(q1)
    srv.submit(q2)
    srv.flush()
    srv.submit(q1)  # repeat → cache path
    srv.flush()
    st = srv.stats()
    samples = parse_prometheus(srv.prometheus_text())
    assert st["completed"] == 3
    assert samples["serve_queries_completed_total"] == st["completed"]
    assert samples["serve_cache_hits_total"] == st["cache_hits"]
    assert samples['serve_batches_total{bucket="8"}'] == sum(
        st["batches_per_bucket"].values()
    )
    assert samples["serve_lanes_run_total"] == st["lanes_run"]


def test_serve_emits_query_spans():
    from repro.serve import ServeConfig, SteinerServer

    g, n, _ = _instance(0)
    obs.enable()
    srv = SteinerServer(
        g, ServeConfig(buckets=(8,), max_batch=4, cache_capacity=16)
    )
    rng = np.random.default_rng(1)
    srv.submit(rng.choice(n, size=4, replace=False).tolist())
    srv.flush()
    names = {e["name"] for e in obs.tracer().events()}
    assert {
        "serve:queue_wait",
        "serve:assemble",
        "serve:solve",
        "serve:stash",
    } <= names
    assert validate_chrome_trace(obs.tracer().chrome_trace()) > 0


# ----------------------------------------------------------------------------
# CLI surfaces — graphstore flags and the obs validator
# ----------------------------------------------------------------------------


def _run_graphstore(args):
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.run(
        [sys.executable, "-m", "repro.graphstore", *args],
        capture_output=True,
        text=True,
        env=env,
    )


def test_graphstore_cli_json_and_quiet(tmp_path):
    store = tmp_path / "g.gstore"
    r = _run_graphstore(
        ["--json", "build", str(store), "--source", "rmat",
         "--scale", "6", "--edge-factor", "4"]
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads(r.stdout)  # stdout is exactly one JSON document
    assert doc["cmd"] == "build" and doc["m_directed"] > 0
    assert "built" in r.stderr  # progress rides the logger on stderr

    r = _run_graphstore(
        ["--json", "--quiet", "partition", str(store), "--blocks", "2"]
    )
    assert r.returncode == 0 and r.stderr == ""
    doc = json.loads(r.stdout)
    assert doc["cmd"] == "partition" and doc["shards"] == 2
    assert doc["meta"]["scheme"] == "1d"

    r = _run_graphstore(["--json", "--quiet", "info", str(store)])
    assert r.returncode == 0 and r.stderr == ""
    doc = json.loads(r.stdout)
    assert doc["partition"]["scheme"] == "1d"
    assert doc["degree"]["max"] >= doc["degree"]["min"]


def test_graphstore_cli_trace_and_metrics(tmp_path):
    store = tmp_path / "g.gstore"
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.txt"
    r = _run_graphstore(
        ["--quiet", "--trace", str(trace), "--metrics", str(metrics),
         "build", str(store), "--source", "rmat",
         "--scale", "6", "--edge-factor", "4"]
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads(trace.read_text())
    assert validate_chrome_trace(doc) > 0
    names = {e["name"] for e in doc["traceEvents"]}
    assert "ingest:build_store" in names
    assert "ingest:pass1_degrees" in names and "ingest:chunk" in names
    samples = parse_prometheus(metrics.read_text())
    assert samples["graphstore_ingest_edges_total"] > 0


def test_obs_cli_validate(tmp_path):
    tr = Tracer()
    with tr.span("build"):
        pass
    trace = tmp_path / "t.json"
    tr.export_chrome(str(trace))
    reg = MetricsRegistry()
    reg.counter("x_total").inc(2)
    metrics = tmp_path / "m.txt"
    metrics.write_text(reg.prometheus_text())
    ok = obs_main(
        ["validate", str(trace), "--metrics", str(metrics),
         "--require-span", "build"]
    )
    assert ok == 0
    assert obs_main(["validate", str(trace), "--require-span", "nope"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"ph": "Z", "ts": 0.0}]))
    assert obs_main(["validate", str(bad)]) == 1
    metrics.write_text("not { prometheus\n")
    assert obs_main(["validate", str(trace), "--metrics", str(metrics)]) == 1
