"""Voronoi cell computation vs the multi-source Dijkstra oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import from_edges, to_ell
from repro.core import ref
from repro.core.voronoi import voronoi_cells, voronoi_cells_frontier
from repro.kernels.minplus.ops import (
    voronoi_cells_pallas,
    voronoi_cells_pallas_frontier,
)

from helpers import random_instance


@pytest.mark.parametrize("mode", ["dense", "bucket"])
@pytest.mark.parametrize("trial", range(6))
def test_voronoi_matches_dijkstra(mode, trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, stats = voronoi_cells(g, jnp.asarray(seeds), mode=mode)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)
    assert int(stats.iterations) > 0


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_frontier_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=32)
    st_, _ = voronoi_cells_frontier(ell, jnp.asarray(seeds), frontier_size=32)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_pallas_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=64)
    st_, stats = voronoi_cells_pallas(ell, jnp.asarray(seeds), block_rows=64)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)
    # real convergence stats, not the old zero placeholder
    assert float(stats.relaxations) > 0
    assert float(stats.messages) > 0


@pytest.mark.parametrize("src_block", [None, 32])
@pytest.mark.parametrize("trial", range(3))
def test_voronoi_pallas_frontier_matches(trial, src_block):
    """Top-K compacted kernel schedule: same fixpoint as the oracle, for
    both the VMEM-resident and the source-blocked kernel."""
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=64)
    st_, stats = voronoi_cells_pallas_frontier(
        ell,
        jnp.asarray(seeds),
        frontier_size=32,
        block_rows=16,
        src_block=src_block,
    )
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)
    assert float(stats.relaxations) > 0


def test_bucket_delta_zero_rejected():
    """delta<=0 never advances the bucket threshold — formerly a silent
    spin through the full 4n+64 round cap."""
    src, dst, w, n, seeds, edges = random_instance(0)
    g = from_edges(src, dst, w, n, pad_to=8)
    with pytest.raises(ValueError, match="delta must be positive"):
        voronoi_cells(g, jnp.asarray(seeds), mode="bucket", delta=0.0)
    with pytest.raises(ValueError, match="delta must be positive"):
        voronoi_cells(g, jnp.asarray(seeds), mode="bucket", delta=-1.5)
    # dense mode documents delta as bucket-only and ignores it — no raise
    st_, _ = voronoi_cells(g, jnp.asarray(seeds), mode="dense", delta=0.0)
    dist, _, _ = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)


def test_bucket_delta_traced_rejected_loudly():
    """Δ is a static knob: a traced value can no longer bypass validation
    and stall the bucket loop (the PR-4 bug class) — it is rejected
    outright on the host path, before any trace runs."""
    import jax

    src, dst, w, n, seeds, edges = random_instance(0)
    g = from_edges(src, dst, w, n, pad_to=8)
    f = jax.jit(
        lambda d: voronoi_cells(g, jnp.asarray(seeds), mode="bucket", delta=d)
    )
    with pytest.raises(TypeError, match="host scalar"):
        f(0.0)
    # host scalars still validate eagerly, including numpy scalars
    with pytest.raises(ValueError, match="delta must be positive"):
        voronoi_cells(
            g, jnp.asarray(seeds), mode="bucket", delta=np.float32(0.0)
        )
    # and a positive numpy scalar is a valid static width
    st_, _ = voronoi_cells(
        g, jnp.asarray(seeds), mode="bucket", delta=np.float32(2.0)
    )
    dist, _, _ = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)


def test_voronoi_cells_frontier_mode_redirect():
    """The COO entry point's unknown-mode error points at the dedicated
    frontier/pallas entry points instead of implying two modes exist."""
    src, dst, w, n, seeds, edges = random_instance(0)
    g = from_edges(src, dst, w, n, pad_to=8)
    with pytest.raises(ValueError, match="voronoi_cells_frontier"):
        voronoi_cells(g, jnp.asarray(seeds), mode="frontier")
    with pytest.raises(ValueError, match="voronoi_cells_pallas"):
        voronoi_cells(g, jnp.asarray(seeds), mode="pallas")


def test_bucket_fewer_messages_than_dense():
    """The paper's Fig. 5/6 effect: prioritization cuts message volume.

    A wide edge-weight range ([1, 500], paper Fig. 7) makes FIFO/dense
    propagation waste many soon-overwritten updates; Δ-bucketed priority
    suppresses them.
    """
    from repro.data.graphs import rmat_edges

    src, dst, w, n = rmat_edges(8, 8, max_weight=500, seed=12)
    rng = np.random.default_rng(12)
    seeds = rng.choice(n, size=8, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    _, s_dense = voronoi_cells(g, jnp.asarray(seeds), mode="dense")
    _, s_buck = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    # strictly fewer generated messages AND fewer overwritten updates
    assert float(s_buck.messages) < float(s_dense.messages)
    assert float(s_buck.relaxations) <= float(s_dense.relaxations)
