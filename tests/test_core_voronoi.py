"""Voronoi cell computation vs the multi-source Dijkstra oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import from_edges, to_ell
from repro.core import ref
from repro.core.voronoi import voronoi_cells, voronoi_cells_frontier
from repro.kernels.minplus.ops import voronoi_cells_pallas

from helpers import random_instance


@pytest.mark.parametrize("mode", ["dense", "bucket"])
@pytest.mark.parametrize("trial", range(6))
def test_voronoi_matches_dijkstra(mode, trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, stats = voronoi_cells(g, jnp.asarray(seeds), mode=mode)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)
    assert int(stats.iterations) > 0


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_frontier_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=32)
    st_, _ = voronoi_cells_frontier(ell, jnp.asarray(seeds), frontier_size=32)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_pallas_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=64)
    st_, _ = voronoi_cells_pallas(ell, jnp.asarray(seeds), block_rows=64)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)


def test_bucket_fewer_messages_than_dense():
    """The paper's Fig. 5/6 effect: prioritization cuts message volume.

    A wide edge-weight range ([1, 500], paper Fig. 7) makes FIFO/dense
    propagation waste many soon-overwritten updates; Δ-bucketed priority
    suppresses them.
    """
    from repro.data.graphs import rmat_edges

    src, dst, w, n = rmat_edges(8, 8, max_weight=500, seed=12)
    rng = np.random.default_rng(12)
    seeds = rng.choice(n, size=8, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    _, s_dense = voronoi_cells(g, jnp.asarray(seeds), mode="dense")
    _, s_buck = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    # strictly fewer generated messages AND fewer overwritten updates
    assert float(s_buck.messages) < float(s_dense.messages)
    assert float(s_buck.relaxations) <= float(s_dense.relaxations)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 40),
    p=st.floats(0.1, 0.5),
    nseeds=st.integers(2, 6),
    rngseed=st.integers(0, 10**6),
)
def test_voronoi_property(n, p, nseeds, rngseed):
    """Property: Voronoi invariants hold on arbitrary random graphs.

    dist is a fixpoint of min-plus relaxation; lab is consistent along pred
    chains; every reached vertex's pred chain terminates at its seed.
    """
    from repro.data.graphs import er_edges

    src, dst, w, n_, seeds_all = *er_edges(n, p, max_weight=12, seed=rngseed), None
    src, dst, w, n2 = src, dst, w, n
    rng = np.random.default_rng(rngseed)
    seeds = rng.choice(n, size=nseeds, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, _ = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    dist = np.asarray(st_.dist)
    lab = np.asarray(st_.lab)
    pred = np.asarray(st_.pred)
    # (1) fixpoint: no edge can improve any vertex
    for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
        if np.isfinite(dist[u]):
            assert dist[v] <= dist[u] + wt + 1e-5
        if np.isfinite(dist[v]):
            assert dist[u] <= dist[v] + wt + 1e-5
    # (2) label consistency + chain termination
    for v in range(n):
        if not np.isfinite(dist[v]):
            continue
        assert lab[v] == lab[pred[v]]
        x, hops = v, 0
        while pred[x] != x and hops <= n + 1:
            assert dist[pred[x]] < dist[x] + 1e-9
            x = int(pred[x])
            hops += 1
        assert x == seeds[lab[v]]
