"""Voronoi cell computation vs the multi-source Dijkstra oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import from_edges, to_ell
from repro.core import ref
from repro.core.voronoi import voronoi_cells, voronoi_cells_frontier
from repro.kernels.minplus.ops import voronoi_cells_pallas

from helpers import random_instance


@pytest.mark.parametrize("mode", ["dense", "bucket"])
@pytest.mark.parametrize("trial", range(6))
def test_voronoi_matches_dijkstra(mode, trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, stats = voronoi_cells(g, jnp.asarray(seeds), mode=mode)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)
    assert int(stats.iterations) > 0


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_frontier_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=32)
    st_, _ = voronoi_cells_frontier(ell, jnp.asarray(seeds), frontier_size=32)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)


@pytest.mark.parametrize("trial", range(3))
def test_voronoi_pallas_matches(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    ell = to_ell(g, k=8, pad_rows_to=64)
    st_, _ = voronoi_cells_pallas(ell, jnp.asarray(seeds), block_rows=64)
    dist, lab, pred = ref.voronoi_ref(n, edges, seeds.tolist())
    np.testing.assert_allclose(np.asarray(st_.dist), dist)
    np.testing.assert_array_equal(np.asarray(st_.lab), lab)
    np.testing.assert_array_equal(np.asarray(st_.pred), pred)


def test_bucket_fewer_messages_than_dense():
    """The paper's Fig. 5/6 effect: prioritization cuts message volume.

    A wide edge-weight range ([1, 500], paper Fig. 7) makes FIFO/dense
    propagation waste many soon-overwritten updates; Δ-bucketed priority
    suppresses them.
    """
    from repro.data.graphs import rmat_edges

    src, dst, w, n = rmat_edges(8, 8, max_weight=500, seed=12)
    rng = np.random.default_rng(12)
    seeds = rng.choice(n, size=8, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    _, s_dense = voronoi_cells(g, jnp.asarray(seeds), mode="dense")
    _, s_buck = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    # strictly fewer generated messages AND fewer overwritten updates
    assert float(s_buck.messages) < float(s_dense.messages)
    assert float(s_buck.relaxations) <= float(s_dense.relaxations)
