"""End-to-end Steiner pipeline vs Mehlhorn / KMB / exact oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import from_edges, steiner_tree, tree_edge_list
from repro.core import ref

from helpers import random_instance


@pytest.mark.parametrize("mode", ["dense", "bucket", "frontier"])
@pytest.mark.parametrize("mst_algo", ["prim", "boruvka"])
@pytest.mark.parametrize("trial", range(4))
def test_pipeline_matches_mehlhorn(mode, mst_algo, trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    t_ref, d_ref = ref.mehlhorn_ref(n, edges, seeds.tolist())
    res = steiner_tree(g, jnp.asarray(seeds), mode=mode, mst_algo=mst_algo)
    assert abs(float(res.tree.total_distance) - d_ref) < 1e-4
    assert tree_edge_list(res.state, res.tree) == t_ref


@pytest.mark.parametrize("trial", range(6))
def test_two_approximation_bound(trial):
    """Paper Table VII: D(G_S)/D_min <= 2(1 - 1/l) <= 2(1 - 1/|S|')."""
    src, dst, w, n, seeds, edges = random_instance(trial, n_seeds=5)
    g = from_edges(src, dst, w, n, pad_to=8)
    res = steiner_tree(g, jnp.asarray(seeds))
    d = float(res.tree.total_distance)
    opt = ref.dreyfus_wagner(n, edges, seeds.tolist())
    assert d >= opt - 1e-4  # can't beat the optimum
    assert d <= 2.0 * (1.0 - 1.0 / len(seeds)) * opt + 1e-4


@pytest.mark.parametrize("trial", range(6))
def test_tree_validity(trial):
    src, dst, w, n, seeds, edges = random_instance(trial)
    g = from_edges(src, dst, w, n, pad_to=8)
    res = steiner_tree(g, jnp.asarray(seeds))
    tset = tree_edge_list(res.state, res.tree)
    assert ref.tree_is_valid(n, edges, seeds.tolist(), tset)
    assert len(tset) == int(res.tree.num_edges)


def test_two_seeds_is_shortest_path():
    """|S| = 2 degenerates to a shortest weighted path (paper §I)."""
    import scipy.sparse.csgraph as csg

    src, dst, w, n, _, edges = random_instance(0)
    seeds = np.asarray([0, n - 1], np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    res = steiner_tree(g, jnp.asarray(seeds))
    sp = csg.dijkstra(ref._min_csr(n, edges), indices=[0])[0, n - 1]
    assert abs(float(res.tree.total_distance) - sp) < 1e-4


def test_kmb_agrees_on_total_bound():
    """KMB and Mehlhorn share the bound; both stay within it."""
    src, dst, w, n, seeds, edges = random_instance(2)
    _, d_kmb = ref.kmb_ref(n, edges, seeds.tolist())
    _, d_meh = ref.mehlhorn_ref(n, edges, seeds.tolist())
    opt = ref.dreyfus_wagner(n, edges, seeds.tolist())
    bound = 2.0 * (1.0 - 1.0 / len(seeds)) * opt + 1e-4
    assert d_kmb <= bound and d_meh <= bound


def test_frontier_dispatch_accepts_prebuilt_ell():
    """mode="frontier" through the steiner_tree front door, both with the
    host-built default ELL view and a caller-supplied one."""
    from repro.core import to_ell

    src, dst, w, n, seeds, edges = random_instance(1)
    g = from_edges(src, dst, w, n, pad_to=8)
    _, d_ref = ref.mehlhorn_ref(n, edges, seeds.tolist())
    auto = steiner_tree(g, jnp.asarray(seeds), mode="frontier")
    ell = to_ell(g, k=8, pad_rows_to=32)
    pre = steiner_tree(g, jnp.asarray(seeds), mode="frontier", ell=ell)
    assert abs(float(auto.tree.total_distance) - d_ref) < 1e-4
    assert abs(float(pre.tree.total_distance) - d_ref) < 1e-4


def test_unknown_mode_raises():
    src, dst, w, n, seeds, _ = random_instance(0)
    g = from_edges(src, dst, w, n, pad_to=8)
    with pytest.raises(ValueError, match="unknown mode"):
        steiner_tree(g, jnp.asarray(seeds), mode="fifo")
