"""Substrate tests: checkpointing (incl. crash/restart), compression, data."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data.recsys import BehaviorStream
from repro.data.tokens import TokenStream
from repro.distributed.compression import compress_tree, init_error, _dequant


def test_save_load_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.bfloat16(2.5)},
    }
    save_pytree(tree, tmp_path / "x.npz")
    back = load_pytree(tree, tmp_path / "x.npz")
    for l0, l1 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_manager_rolling_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        mgr.save(s, {"w": jnp.full((4,), s, jnp.float32)}, blocking=True)
    assert mgr.latest_step() == 30
    assert sorted(mgr.steps()) == [20, 30]  # rolled
    step, st = mgr.restore({"w": jnp.zeros((4,), jnp.float32)})
    assert step == 30 and float(st["w"][0]) == 30


def test_crash_restart_resumes_to_same_loss(tmp_path):
    """Paper-grade fault tolerance: killed job resumes bit-comparable."""
    from repro.launch.train import TrainConfig, train

    base = dict(
        arch="starcoder2-3b",
        steps=24,
        batch=2,
        seq_len=32,
        ckpt_every=8,
        lr=1e-3,
    )
    # uninterrupted reference
    cfg_ref = TrainConfig(ckpt_dir=str(tmp_path / "ref"), **base)
    _, _, losses_ref = train(cfg_ref, log=lambda *_: None)
    # crash at step 17, then relaunch
    cfg_crash = TrainConfig(
        ckpt_dir=str(tmp_path / "crash"), failure_at_step=17, **base
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg_crash, log=lambda *_: None)
    cfg_resume = TrainConfig(ckpt_dir=str(tmp_path / "crash"), **base)
    _, _, losses_resumed = train(cfg_resume, log=lambda *_: None)
    # the resumed tail must match the reference tail (same data, same state)
    np.testing.assert_allclose(losses_resumed[-1], losses_ref[-1], rtol=1e-4)


def test_compression_error_feedback_converges():
    """Mean of compressed grads over steps ≈ mean of true grads."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((300,), jnp.float32)}
    err = init_error(params)
    acc_true = np.zeros(300)
    acc_q = np.zeros(300)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=300) * (1 + np.arange(300) / 50), jnp.float32)}
        qtree, err = compress_tree(g, err)
        q, s = qtree["w"]
        deq = _dequant(q, s, (300,), jnp.float32)
        acc_true += np.asarray(g["w"])
        acc_q += np.asarray(deq)
    # error feedback keeps the ACCUMULATED signal nearly unbiased
    denom = np.abs(acc_true).mean()
    assert np.abs(acc_q - acc_true).mean() < 0.02 * denom


def test_token_stream_deterministic_and_seekable():
    s1 = TokenStream(1000, 4, 16, seed=3)
    s2 = TokenStream(1000, 4, 16, seed=3)
    np.testing.assert_array_equal(s1.batch_at(7), s2.batch_at(7))
    assert not np.array_equal(s1.batch_at(7), s1.batch_at(8))
    assert s1.batch_at(7).shape == (4, 16)
    assert s1.batch_at(7).max() < 1000


def test_behavior_stream_targets_share_cluster():
    bs = BehaviorStream(10_000, 12, 8, seed=1)
    b = bs.batch_at(0)
    assert b["hist_ids"].shape == (8, 12)
    assert b["target_id"].shape == (8,)
    assert (b["hist_mask"].sum(1) >= 6).all()


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore onto an explicit sharding (mesh relayout path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat

    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    save_pytree(tree, tmp_path / "e.npz")
    mesh = compat.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    back = load_pytree(tree, tmp_path / "e.npz", shardings=sh)
    assert back["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(back["w"]), np.arange(16))
