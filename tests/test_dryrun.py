"""Dry-run guard: one LM cell must lower+compile on both production meshes.

Full sweeps live in benchmarks/results/dryrun/ (43 cells × 2 meshes); this
test keeps the machinery honest in CI at ~2 min by compiling the cheapest
cell (starcoder2 decode) end-to-end in a 512-device subprocess.
"""

import os
import subprocess
import sys

import pytest

_DIR = os.path.dirname(__file__)
_SRC = os.path.abspath(os.path.join(_DIR, "..", "src"))

_PROG = r"""
import sys, json
from pathlib import Path
from repro.launch.dryrun import run_cell
from repro.configs import get_arch
out = Path(sys.argv[1])
shape = [s for s in get_arch("starcoder2-3b").shapes if s.name == "decode_32k"][0]
for mp in (False, True):
    rec = run_cell("starcoder2-3b", shape, mp, out, force=True)
    assert rec["status"] == "ok", rec
    assert rec["memory"]["fits_16gb"], rec["memory"]
    assert rec["roofline"]["dominant"] in ("memory", "collective", "compute")
print("DRYRUN_OK")
"""


@pytest.mark.slow
def test_dryrun_cell_both_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-c", _PROG, str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "DRYRUN_OK" in proc.stdout


def test_roofline_collective_parser():
    """HLO collective-byte parsing on a hand-written snippet."""
    from repro.launch.roofline import collective_bytes

    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %cp = u8[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %notacoll = f32[999]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 4 * 2  # ring factor 2
    assert out["reduce-scatter"] == 64 * 4
    assert out["collective-permute"] == 128
    assert out["all-to-all"] == 0


def test_registry_shapes_cover_assignment():
    """40 assigned cells: 5 LM × 4 + 4 GNN × 4 + 1 recsys × 4."""
    from repro.configs import ARCH_IDS, get_arch

    cells = 0
    for a in ARCH_IDS:
        cells += len(get_arch(a).shapes)
    assert cells == 40
