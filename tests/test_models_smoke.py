"""Per-architecture smoke tests: reduced configs, real forward/train steps.

Each assigned arch instantiates its REDUCED config and runs 1-2 real
optimizer steps (and a decode step for LMs) on CPU, asserting output
shapes and the absence of NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, get_arch
from repro.configs.base import ShapeSpec
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tf_mod
from repro.optim import OptConfig, adamw_init

LM_IDS = [
    "deepseek-v3-671b",
    "granite-moe-1b-a400m",
    "qwen1.5-32b",
    "stablelm-12b",
    "starcoder2-3b",
]
GNN_IDS = ["graphsage-reddit", "graphcast", "schnet", "gatedgcn"]


def _finite(tree):
    return all(
        bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
        for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_train(arch_id):
    cfg = get_arch(arch_id).reduced
    rng = jax.random.PRNGKey(0)
    params = tf_mod.init_params(cfg, rng)
    opt_cfg = OptConfig(lr=1e-3)
    opt_state = adamw_init(params, opt_cfg)
    step = jax.jit(tf_mod.make_train_step(cfg, opt_cfg, dp_axes=()))
    tokens = jax.random.randint(rng, (4, 32), 0, cfg.vocab)
    losses = []
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state, tokens)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0]  # it learns the batch
    assert _finite(params)


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_decode(arch_id):
    cfg = get_arch(arch_id).reduced
    rng = jax.random.PRNGKey(1)
    params = tf_mod.init_params(cfg, rng)
    B, smax = 2, 16
    L = cfg.n_layers
    Ld = cfg.first_dense_layers if cfg.moe else cfg.n_layers
    Lm = cfg.n_layers - Ld if cfg.moe else 0

    def zero_cache(nl):
        if cfg.mla:
            lat = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            return jnp.zeros((nl, B, smax, lat), cfg.jdtype)
        if cfg.kv_quant_int8:
            return (
                jnp.zeros((nl, B, smax, cfg.n_kv_heads, cfg.hd), jnp.int8),
                jnp.zeros((nl, B, smax, cfg.n_kv_heads, 1), jnp.bfloat16),
                jnp.zeros((nl, B, smax, cfg.n_kv_heads, cfg.hd), jnp.int8),
                jnp.zeros((nl, B, smax, cfg.n_kv_heads, 1), jnp.bfloat16),
            )
        return (
            jnp.zeros((nl, B, smax, cfg.n_kv_heads, cfg.hd), cfg.jdtype),
            jnp.zeros((nl, B, smax, cfg.n_kv_heads, cfg.hd), cfg.jdtype),
        )

    caches = {}
    if Ld:
        caches["dense"] = zero_cache(Ld)
    if Lm:
        caches["moe"] = zero_cache(Lm)
    step = jax.jit(tf_mod.make_decode_step(cfg, dp_axes=()))
    tok = jnp.array([1, 2], jnp.int32)
    logits, caches = step(params, caches, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # second token
    logits2, caches = step(params, caches, tok, jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def _gnn_batch(cfg, shape, rng):
    r = np.random.default_rng(0)
    N, E, F = shape.n_nodes, shape.n_edges, shape.d_feat
    edges = jnp.asarray(r.integers(0, N, (E, 2)), jnp.int32)
    if cfg.kind == "sage" and shape.kind == "gnn_sampled":
        B = shape.batch_nodes
        f1, f2 = shape.fanout
        return {
            "feats": (
                jnp.asarray(r.normal(size=(B, F)), jnp.float32),
                jnp.asarray(r.normal(size=(B * f1, F)), jnp.float32),
                jnp.asarray(r.normal(size=(B * f1 * f2, F)), jnp.float32),
            ),
            "labels": jnp.asarray(r.integers(0, cfg.n_classes, B), jnp.int32),
        }
    if cfg.kind == "sage":
        return {
            "x": jnp.asarray(r.normal(size=(N, F)), jnp.float32),
            "edges": edges,
            "labels": jnp.asarray(r.integers(0, cfg.n_classes, N), jnp.int32),
        }
    if cfg.kind == "gatedgcn":
        return {
            "x": jnp.asarray(r.normal(size=(N, F)), jnp.float32),
            "edges": edges,
            "ew": jnp.asarray(r.uniform(size=(E,)), jnp.float32),
            "labels": jnp.asarray(r.integers(0, cfg.n_classes, N), jnp.int32),
        }
    if cfg.kind == "schnet":
        if shape.kind == "gnn_batched":
            G = shape.graph_batch
            return {
                "z": jnp.asarray(r.normal(size=(G, N, F)), jnp.float32),
                "pos": jnp.asarray(r.normal(size=(G, N, 3)), jnp.float32),
                "edges_t": edges,
                "energy": jnp.asarray(r.normal(size=(G,)), jnp.float32),
            }
        return {
            "x": jnp.asarray(r.normal(size=(N, F)), jnp.float32),
            "pos": jnp.asarray(r.normal(size=(N, 3)), jnp.float32),
            "edges": edges,
            "energy_sum": jnp.float32(1.0),
        }
    if cfg.kind == "graphcast":
        em = min(E, 8 * (N // 4 + 1))
        nm = N // 4 + 1
        return {
            "x": jnp.asarray(r.normal(size=(N, F)), jnp.float32),
            "g2m": jnp.asarray(
                np.stack([r.integers(0, N, E), r.integers(0, nm, E)], 1), jnp.int32
            ),
            "mesh_e": jnp.asarray(r.integers(0, nm, (em, 2)), jnp.int32),
            "m2g": jnp.asarray(
                np.stack([r.integers(0, nm, E), r.integers(0, N, E)], 1), jnp.int32
            ),
            "target": jnp.asarray(r.normal(size=(N, cfg.n_vars)), jnp.float32),
        }
    raise ValueError(cfg.kind)


@pytest.mark.parametrize("arch_id", GNN_IDS)
@pytest.mark.parametrize("kind", ["gnn_full", "gnn_sampled", "gnn_batched"])
def test_gnn_smoke(arch_id, kind):
    cfg = get_arch(arch_id).reduced
    if kind == "gnn_sampled" and cfg.kind != "sage":
        pytest.skip("sampled shape exercised via sage only at smoke scale")
    if kind == "gnn_batched" and cfg.kind != "schnet":
        pytest.skip("molecule batching exercised via schnet at smoke scale")
    shape = ShapeSpec(
        name="smoke",
        kind=kind,
        n_nodes=24,
        n_edges=80,
        d_feat=16,
        batch_nodes=8,
        fanout=(3, 2),
        graph_batch=4,
    )
    rng = jax.random.PRNGKey(0)
    params = gnn_mod.init_params(cfg, shape.d_feat, rng)
    opt_cfg = OptConfig(lr=1e-3)
    opt_state = adamw_init(params, opt_cfg)
    step = jax.jit(gnn_mod.make_train_step(cfg, shape, opt_cfg))
    batch = _gnn_batch(cfg, shape, rng)
    losses = []
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0]
    assert _finite(params)


def test_recsys_smoke_train_and_serve():
    cfg = get_arch("mind").reduced
    rng = jax.random.PRNGKey(0)
    params = rec_mod.init_params(cfg, rng)
    opt_cfg = OptConfig(lr=1e-2)
    opt_state = adamw_init(params, opt_cfg)
    r = np.random.default_rng(0)
    B = 16
    batch = {
        "hist_ids": jnp.asarray(r.integers(0, cfg.n_items, (B, cfg.hist_len)), jnp.int32),
        "hist_mask": jnp.ones((B, cfg.hist_len), jnp.float32),
        "target_id": jnp.asarray(r.integers(0, cfg.n_items, B), jnp.int32),
    }
    tshape = ShapeSpec(name="t", kind="recsys_train", batch=B)
    step = jax.jit(rec_mod.make_step(cfg, tshape, opt_cfg))
    losses = []
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    # serve + retrieval paths
    sshape = ShapeSpec(name="s", kind="recsys_serve", batch=4)
    sbatch = {
        "hist_ids": batch["hist_ids"][:4],
        "hist_mask": batch["hist_mask"][:4],
        "cand_ids": jnp.asarray(r.integers(0, cfg.n_items, (4, 32)), jnp.int32),
    }
    scores = jax.jit(rec_mod.make_step(cfg, sshape))(params, sbatch)
    assert scores.shape == (4, 32) and bool(jnp.all(jnp.isfinite(scores)))
    rshape = ShapeSpec(name="r", kind="recsys_retrieval", batch=1, n_candidates=100)
    rbatch = {
        "hist_ids": batch["hist_ids"][:1],
        "hist_mask": batch["hist_mask"][:1],
        "cand_ids": jnp.asarray(r.integers(0, cfg.n_items, (100,)), jnp.int32),
    }
    rs = jax.jit(rec_mod.make_step(cfg, rshape))(params, rbatch)
    assert rs.shape == (100,) and bool(jnp.all(jnp.isfinite(rs)))


def test_registry_covers_all_archs():
    assert len(ALL_IDS) == 11  # 10 assigned + the paper's own
    for a in ALL_IDS:
        spec = get_arch(a)
        assert spec.reduced is not None
        assert len(spec.shapes) >= 3


def test_quantized_adamw_tracks_fp32():
    """8-bit Adam stays close to fp32 Adam over a few steps."""
    from repro.optim import adamw_update

    rng = np.random.default_rng(0)
    p0 = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)}
    cfg_f = OptConfig(lr=1e-2, quantized=False, weight_decay=0.0)
    cfg_q = OptConfig(lr=1e-2, quantized=True, weight_decay=0.0)
    pf, pq = p0, p0
    sf, sq = adamw_init(p0, cfg_f), adamw_init(p0, cfg_q)
    for i in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32) * 0.1}
        pf, sf = adamw_update(pf, g, sf, cfg_f)
        pq, sq = adamw_update(pq, g, sq, cfg_q)
    diff = float(jnp.max(jnp.abs(pf["w"] - pq["w"])))
    scale = float(jnp.max(jnp.abs(pf["w"] - p0["w"])))
    assert diff < 0.15 * scale, (diff, scale)
