"""Hypothesis property tests for the Voronoi and Steiner pipelines.

Kept in their own module so the module-level ``importorskip`` skips ONLY
the property tests on environments without ``hypothesis`` — the
deterministic core tests in test_core_voronoi.py / test_core_steiner.py
run everywhere.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import from_edges, steiner_tree, tree_edge_list
from repro.core import ref
from repro.core.voronoi import voronoi_cells


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 40),
    p=st.floats(0.1, 0.5),
    nseeds=st.integers(2, 6),
    rngseed=st.integers(0, 10**6),
)
def test_voronoi_property(n, p, nseeds, rngseed):
    """Property: Voronoi invariants hold on arbitrary random graphs.

    dist is a fixpoint of min-plus relaxation; lab is consistent along pred
    chains; every reached vertex's pred chain terminates at its seed.
    """
    from repro.data.graphs import er_edges

    src, dst, w, _ = er_edges(n, p, max_weight=12, seed=rngseed)
    rng = np.random.default_rng(rngseed)
    seeds = rng.choice(n, size=nseeds, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, _ = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    dist = np.asarray(st_.dist)
    lab = np.asarray(st_.lab)
    pred = np.asarray(st_.pred)
    # (1) fixpoint: no edge can improve any vertex
    for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
        if np.isfinite(dist[u]):
            assert dist[v] <= dist[u] + wt + 1e-5
        if np.isfinite(dist[v]):
            assert dist[u] <= dist[v] + wt + 1e-5
    # (2) label consistency + chain termination
    for v in range(n):
        if not np.isfinite(dist[v]):
            continue
        assert lab[v] == lab[pred[v]]
        x, hops = v, 0
        while pred[x] != x and hops <= n + 1:
            assert dist[pred[x]] < dist[x] + 1e-9
            x = int(pred[x])
            hops += 1
        assert x == seeds[lab[v]]


@st.composite
def _delta_instance(draw):
    """A small base graph plus a random op interleaving, pre-split into
    1-3 append segments."""
    n = draw(st.integers(6, 20))
    pair = st.tuples(
        st.integers(0, n - 1), st.integers(0, n - 1)
    ).filter(lambda t: t[0] != t[1])
    base = [
        (u, v, float(w))
        for ((u, v), w) in draw(
            st.lists(st.tuples(pair, st.integers(1, 30)),
                     min_size=3, max_size=40)
        )
    ]
    raw_ops = draw(
        st.lists(
            st.tuples(
                st.sampled_from(["add", "delete", "reweight"]),
                pair,
                st.integers(1, 30),
            ),
            max_size=30,
        )
    )
    ops = [
        ("delete", u, v) if kind == "delete" else (kind, u, v, float(w))
        for (kind, (u, v), w) in raw_ops
    ]
    nseg = draw(st.integers(1, 3))
    cut = sorted(
        draw(st.lists(st.integers(0, len(ops)), min_size=nseg - 1,
                      max_size=nseg - 1))
    )
    bounds = [0] + cut + [len(ops)]
    segments = [ops[a:b] for a, b in zip(bounds, bounds[1:])]
    return n, base, segments


@settings(max_examples=25, deadline=None)
@given(inst=_delta_instance())
def test_delta_fold_compact_bit_identical_property(inst):
    """Property: for ANY interleaving of add/delete/reweight records over
    any base graph, the overlay view and the compacted store are both
    bit-identical (CSR arrays + weight range) to a fresh ingest of the
    final edge set in canonical order."""
    import shutil
    import tempfile
    from pathlib import Path

    from test_delta import check_append_compact_roundtrip

    n, base, segments = inst
    tmp = Path(tempfile.mkdtemp(prefix="delta_prop_"))
    try:
        check_append_compact_roundtrip(tmp, n, base, segments)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


@settings(max_examples=15, deadline=None)
@given(
    nv=st.integers(10, 36),
    p=st.floats(0.15, 0.5),
    nseeds=st.integers(2, 5),
    rngseed=st.integers(0, 10**6),
)
def test_steiner_property(nv, p, nseeds, rngseed):
    """Property: valid tree, D == Mehlhorn oracle, within 2-approx bound."""
    from repro.data.graphs import er_edges

    src, dst, w, n = er_edges(nv, p, max_weight=10, seed=rngseed)
    rng = np.random.default_rng(rngseed)
    seeds = rng.choice(n, size=nseeds, replace=False).astype(np.int32)
    edges = list(zip(src.tolist(), dst.tolist(), w.tolist()))
    g = from_edges(src, dst, w, n, pad_to=8)
    res = steiner_tree(g, jnp.asarray(seeds))
    d = float(res.tree.total_distance)
    tset = tree_edge_list(res.state, res.tree)
    assert ref.tree_is_valid(n, edges, seeds.tolist(), tset)
    _, d_ref = ref.mehlhorn_ref(n, edges, seeds.tolist())
    assert abs(d - d_ref) < 1e-3
    opt = ref.dreyfus_wagner(n, edges, seeds.tolist())
    assert opt - 1e-4 <= d <= 2.0 * (1 - 1 / nseeds) * opt + 1e-4
