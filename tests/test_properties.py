"""Hypothesis property tests for the Voronoi and Steiner pipelines.

Kept in their own module so the module-level ``importorskip`` skips ONLY
the property tests on environments without ``hypothesis`` — the
deterministic core tests in test_core_voronoi.py / test_core_steiner.py
run everywhere.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import from_edges, steiner_tree, tree_edge_list
from repro.core import ref
from repro.core.voronoi import voronoi_cells


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 40),
    p=st.floats(0.1, 0.5),
    nseeds=st.integers(2, 6),
    rngseed=st.integers(0, 10**6),
)
def test_voronoi_property(n, p, nseeds, rngseed):
    """Property: Voronoi invariants hold on arbitrary random graphs.

    dist is a fixpoint of min-plus relaxation; lab is consistent along pred
    chains; every reached vertex's pred chain terminates at its seed.
    """
    from repro.data.graphs import er_edges

    src, dst, w, _ = er_edges(n, p, max_weight=12, seed=rngseed)
    rng = np.random.default_rng(rngseed)
    seeds = rng.choice(n, size=nseeds, replace=False).astype(np.int32)
    g = from_edges(src, dst, w, n, pad_to=8)
    st_, _ = voronoi_cells(g, jnp.asarray(seeds), mode="bucket")
    dist = np.asarray(st_.dist)
    lab = np.asarray(st_.lab)
    pred = np.asarray(st_.pred)
    # (1) fixpoint: no edge can improve any vertex
    for u, v, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
        if np.isfinite(dist[u]):
            assert dist[v] <= dist[u] + wt + 1e-5
        if np.isfinite(dist[v]):
            assert dist[u] <= dist[v] + wt + 1e-5
    # (2) label consistency + chain termination
    for v in range(n):
        if not np.isfinite(dist[v]):
            continue
        assert lab[v] == lab[pred[v]]
        x, hops = v, 0
        while pred[x] != x and hops <= n + 1:
            assert dist[pred[x]] < dist[x] + 1e-9
            x = int(pred[x])
            hops += 1
        assert x == seeds[lab[v]]


@settings(max_examples=15, deadline=None)
@given(
    nv=st.integers(10, 36),
    p=st.floats(0.15, 0.5),
    nseeds=st.integers(2, 5),
    rngseed=st.integers(0, 10**6),
)
def test_steiner_property(nv, p, nseeds, rngseed):
    """Property: valid tree, D == Mehlhorn oracle, within 2-approx bound."""
    from repro.data.graphs import er_edges

    src, dst, w, n = er_edges(nv, p, max_weight=10, seed=rngseed)
    rng = np.random.default_rng(rngseed)
    seeds = rng.choice(n, size=nseeds, replace=False).astype(np.int32)
    edges = list(zip(src.tolist(), dst.tolist(), w.tolist()))
    g = from_edges(src, dst, w, n, pad_to=8)
    res = steiner_tree(g, jnp.asarray(seeds))
    d = float(res.tree.total_distance)
    tset = tree_edge_list(res.state, res.tree)
    assert ref.tree_is_valid(n, edges, seeds.tolist(), tset)
    _, d_ref = ref.mehlhorn_ref(n, edges, seeds.tolist())
    assert abs(d - d_ref) < 1e-3
    opt = ref.dreyfus_wagner(n, edges, seeds.tolist())
    assert opt - 1e-4 <= d <= 2.0 * (1 - 1 / nseeds) * opt + 1e-4
