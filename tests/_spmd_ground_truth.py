"""Runtime ground truth for the replica-uniformity verdicts (SP01).

Run as a subprocess (forced 8-host-device CPU) by test_analysis_spmd.py:

  * a shard_map program with one correctly psum'd channel and one
    per-rank channel exposed through a rank-axis out_spec → the analyzer
    must stay silent, and at runtime the psum'd channel's replicas are
    bit-identical while the per-rank rows differ AND sum bit-exactly to
    the global channel (the flight-recorder contract);
  * the same body returned through a REPLICATED out_spec without psum →
    the analyzer must flag SP01, and the runtime rows confirm the value
    genuinely varies per rank (the verdict is true, not a false alarm);
  * the real mesh1d executable traced on the same 2×4 mesh → clean, so
    the production telemetry channels' declarations match their values.

Exits 0 iff every static verdict matches the observed runtime behaviour.
"""

import os
import sys

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.spmd.harness import analyze_jaxpr, tiny_graph  # noqa: E402


def main() -> int:
    assert len(jax.devices()) == 8, jax.devices()
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    axes = ("data", "model")

    def body(x):
        local = jnp.sum(x)  # per-rank partial (int32 → sums are exact)
        glob = jax.lax.psum(local, axes)
        # both channels exposed per-rank: a legal, fully-declared program
        return glob.reshape(1), local.reshape(1)

    good = jax.jit(
        compat.shard_map(
            body, mesh=mesh, in_specs=(P(axes),),
            out_specs=(P(axes), P(axes)), check_vma=False,
        )
    )

    def bad_body(x):
        return jnp.sum(x)  # same partial, but claimed replicated below

    bad = jax.jit(
        compat.shard_map(
            bad_body, mesh=mesh, in_specs=(P(axes),), out_specs=P(),
            check_vma=False,
        )
    )

    x = jnp.arange(64, dtype=jnp.int32)

    # ---- static verdicts --------------------------------------------------
    good_findings = analyze_jaxpr(good.trace(x).jaxpr, context="ground/good")
    bad_findings = analyze_jaxpr(bad.trace(x).jaxpr, context="ground/bad")
    assert not [f for f in good_findings if f.rule == "SP01"], [
        f.render() for f in good_findings
    ]
    assert [f for f in bad_findings if f.rule == "SP01"], (
        "analyzer missed the unreduced replicated channel"
    )

    # ---- runtime ground truth on the 2×4 mesh -----------------------------
    glob_rows, local_rows = map(np.asarray, jax.device_get(good(x)))
    assert glob_rows.shape == (8,) and local_rows.shape == (8,)
    # "uniform" verdict: every replica of the psum'd channel is identical
    assert len(set(glob_rows.tolist())) == 1, glob_rows
    # "varying" verdict: the per-rank rows genuinely differ across ranks
    assert len(set(local_rows.tolist())) > 1, local_rows
    # flight-recorder contract: rank rows sum bit-exactly to the global
    assert int(local_rows.sum()) == int(glob_rows[0]) == int(np.arange(64).sum())

    # ---- the real executable on the same mesh is verdict-clean -----------
    from repro.analysis.spmd.harness import _combo_config
    from repro.solver.backends import trace_for_analysis

    cfg = _combo_config("mesh1d", "dense")
    cfg = type(cfg)(**{**cfg.__dict__, "mesh_shape": (2, 4),
                       "telemetry_per_rank": True})
    traced = trace_for_analysis(cfg, tiny_graph(), np.asarray([0, 5, 11], np.int32))
    real = analyze_jaxpr(traced.jaxpr, context="mesh1d/dense@2x4")
    assert real == [], [f.render() for f in real]

    print("ok: SP01 verdicts match the 2x4 forced-host runtime")
    return 0


if __name__ == "__main__":
    sys.exit(main())
